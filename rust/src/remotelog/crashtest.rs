//! Crash-consistency harness: the executable proof of the taxonomy.
//!
//! Runs a REMOTELOG workload against a configuration + method, then
//! injects power failures at many virtual-time points and checks, for
//! each crash, the two contracts a persistence method must uphold:
//!
//! * **Durability** — every append whose persistence point the requester
//!   observed before the crash must be present in the recovered log.
//! * **Integrity** — every recovered record must be byte-identical to
//!   the record the client appended (the recovered log is a true prefix;
//!   no garbage is ever accepted as data).
//!
//! Correct (planner-selected) methods must report zero violations across
//! all crash points and seeds; the paper's incorrect pairings (e.g.
//! one-sided WRITE+FLUSH on a DMP+DDIO responder) must report violations
//! — both directions are asserted by the test suite.

use crate::fabric::timing::Nanos;
use crate::remotelog::client::{AppendMode, AppendRecord, RemoteLog};
use crate::remotelog::log::{LogLayout, RECORD_BYTES};
use crate::remotelog::recovery::{recover, Scanner};
use crate::server::memory::{Image, Layout};
use crate::util::rng::SplitMix64;

/// Aggregated result of a crash sweep.
#[derive(Debug, Clone, Default)]
pub struct CrashReport {
    /// Crash instants checked.
    pub crash_points: u64,
    /// Crashes where an acked append was missing after recovery.
    pub durability_violations: u64,
    /// Crashes where a recovered record didn't match the appended bytes.
    pub integrity_violations: u64,
    /// Compound-mode ordering-contract breaches: the persisted tail
    /// pointer covered records that were NOT durably persisted — `b`
    /// persisted before `a` (paper §3.3). Defensive recovery clamps
    /// these, but an application trusting the ordering contract would
    /// read garbage as committed data.
    pub ordering_violations: u64,
    /// Max number of acked-but-lost appends seen in a single crash.
    pub worst_loss: u64,
}

impl CrashReport {
    /// No violations of any contract?
    pub fn clean(&self) -> bool {
        self.durability_violations == 0
            && self.integrity_violations == 0
            && self.ordering_violations == 0
    }

    /// Accumulate another report.
    pub fn merge(&mut self, other: &CrashReport) {
        self.crash_points += other.crash_points;
        self.durability_violations += other.durability_violations;
        self.integrity_violations += other.integrity_violations;
        self.ordering_violations += other.ordering_violations;
        self.worst_loss = self.worst_loss.max(other.worst_loss);
    }
}

/// Whether the workload's method persists messages that recovery must
/// replay (decided by the client's configured method + mode).
fn needs_replay(rl: &RemoteLog) -> bool {
    match rl.mode {
        AppendMode::Singleton => rl.singleton_method().requires_replay(),
        AppendMode::Compound => rl.compound_method().requires_replay(),
    }
}

/// Check one log's crash contracts against its append oracle — the
/// shared core of the single-client and sharded sweeps.
///
/// * **Durability** — appends acked at or before `t` must be recovered.
/// * **Integrity** — every recovered record matches the oracle
///   byte-for-byte, and recovery never invents records.
/// * **Ordering** — a durable tail pointer never covers a record that
///   is not durably, validly persisted.
pub fn check_log_crash_at(
    image: &Image,
    machine: &Layout,
    log: &LogLayout,
    mode: AppendMode,
    replay: bool,
    appends: &[AppendRecord],
    t: Nanos,
    scanner: &dyn Scanner,
) -> CrashReport {
    let res = recover(image, machine, log, mode, replay, scanner);
    let acked =
        appends.iter().take_while(|a| a.acked_at <= t).count() as u64;

    let mut rep = CrashReport { crash_points: 1, ..Default::default() };
    if res.recovered < acked {
        rep.durability_violations = 1;
        rep.worst_loss = acked - res.recovered;
    }
    // Every recovered record must match the oracle byte-for-byte.
    let n = (res.recovered as usize).min(appends.len());
    for k in 0..n {
        let got = &res.records[k * RECORD_BYTES..(k + 1) * RECORD_BYTES];
        if got != appends[k].record {
            rep.integrity_violations += 1;
        }
    }
    // Recovery can never invent records that were never appended.
    if res.recovered as usize > appends.len() {
        rep.integrity_violations += 1;
    }
    // Compound ordering contract: a durable tail pointer must never
    // cover a record that is not durably, validly persisted.
    if let Some(tp) = res.tail_ptr {
        if tp.min(log.capacity) > res.recovered {
            rep.ordering_violations += 1;
        }
    }
    rep
}

/// Check one crash instant.
pub fn check_crash_at(
    rl: &RemoteLog,
    t: Nanos,
    scanner: &dyn Scanner,
) -> CrashReport {
    let image = rl.fab.mem.crash_image(t, rl.fab.cfg.pdomain);
    check_log_crash_at(
        &image,
        &rl.fab.mem.layout,
        &rl.log,
        rl.mode,
        needs_replay(rl),
        &rl.appends,
        t,
        scanner,
    )
}

/// Sweep crash points over a completed workload: uniform samples plus the
/// adversarial instants just before/at/after every ack (where wrong
/// methods break).
pub fn crash_sweep(
    rl: &RemoteLog,
    uniform_points: u64,
    seed: u64,
    scanner: &dyn Scanner,
) -> CrashReport {
    assert!(
        rl.fab.mem.recording(),
        "crash sweep requires a recording workload run"
    );
    let end = rl.fab.now();
    let mut rng = SplitMix64::new(seed);
    let mut report = CrashReport::default();

    for _ in 0..uniform_points {
        let t = rng.next_below(end.max(1));
        report.merge(&check_crash_at(rl, t, scanner));
    }
    for a in &rl.appends {
        for t in [a.acked_at, a.acked_at + 1, a.acked_at.saturating_sub(1)] {
            report.merge(&check_crash_at(rl, t, scanner));
        }
    }
    // And the quiescent end state.
    report.merge(&check_crash_at(rl, end, scanner));
    report
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fabric::timing::TimingModel;
    use crate::persist::config::{PDomain, RqwrbLoc, ServerConfig};
    use crate::persist::method::{Primary, SingletonMethod};
    use crate::remotelog::client::{AppendMode, MethodChoice};
    use crate::remotelog::recovery::RustScanner;

    fn run(
        cfg: ServerConfig,
        mode: AppendMode,
        choice: MethodChoice,
        seed: u64,
        n: u64,
    ) -> RemoteLog {
        let mut rl = RemoteLog::new(
            cfg,
            TimingModel::default(),
            mode,
            choice,
            n + 8,
            seed,
            true,
        );
        rl.run(n);
        rl
    }

    #[test]
    fn planned_singleton_clean_on_canonical_config() {
        let cfg = ServerConfig::new(PDomain::Dmp, true, RqwrbLoc::Dram);
        let rl = run(
            cfg,
            AppendMode::Singleton,
            MethodChoice::Planned(Primary::Write),
            11,
            40,
        );
        let rep = crash_sweep(&rl, 100, 5, &RustScanner);
        assert!(rep.clean(), "{rep:?}");
    }

    #[test]
    fn planned_compound_clean_on_canonical_config() {
        let cfg = ServerConfig::new(PDomain::Dmp, false, RqwrbLoc::Dram);
        let rl = run(
            cfg,
            AppendMode::Compound,
            MethodChoice::Planned(Primary::Write),
            13,
            40,
        );
        let rep = crash_sweep(&rl, 100, 5, &RustScanner);
        assert!(rep.clean(), "{rep:?}");
    }

    #[test]
    fn one_sided_send_replay_clean() {
        let cfg = ServerConfig::new(PDomain::Mhp, false, RqwrbLoc::Pm);
        let rl = run(
            cfg,
            AppendMode::Singleton,
            MethodChoice::Planned(Primary::Send),
            17,
            40,
        );
        assert_eq!(rl.singleton_method(), SingletonMethod::SendFlush);
        let rep = crash_sweep(&rl, 100, 5, &RustScanner);
        assert!(rep.clean(), "{rep:?}");
    }

    #[test]
    fn wrong_method_flagged() {
        // WRITE+FLUSH on DMP+DDIO: the paper's flagship incorrect pairing.
        let cfg = ServerConfig::new(PDomain::Dmp, true, RqwrbLoc::Dram);
        let rl = run(
            cfg,
            AppendMode::Singleton,
            MethodChoice::ForcedSingleton(SingletonMethod::WriteFlush),
            19,
            20,
        );
        let rep = crash_sweep(&rl, 50, 5, &RustScanner);
        assert!(
            rep.durability_violations > 0,
            "wrong method must lose acked data: {rep:?}"
        );
    }
}
