//! Windowed (pipelined) REMOTELOG client: keep up to `window` appends in
//! flight instead of waiting for each persistence point before issuing
//! the next — the throughput dimension the paper's latency-only
//! evaluation leaves open (§5 mentions pipelining as exactly what the
//! non-posted WRITE enables).
//!
//! Pipelining changes nothing about correctness obligations: an append
//! is "acked" only when *its own* persistence point is observed, and the
//! crash-consistency harness applies unchanged (the campaign in
//! `rust/tests/crash_consistency.rs` covers pipelined runs too).
//!
//! The module also hosts the **cross-shard transactional runner**
//! ([`run_txn_multi_shard`]): every append becomes a transaction
//! spanning all shards, committed with the [`crate::persist::txn`]
//! two-phase protocol, and [`txn_crash_sweep`] proves all-or-nothing
//! recovery at every virtual-time instant (`rust/tests/txn_atomicity.rs`
//! runs the full campaign). With [`TxnRunOpts::replicate`] the decision
//! records are mirrored to a witness QP ([`crate::persist::failover`])
//! and [`run_failover_sweep`] drives the crash × shard-loss cross
//! product (`rust/tests/failover_recovery.rs` runs that campaign).
//! [`run_txn_grouped`] is the **group-commit** variant
//! ([`crate::persist::groupcommit`]): concurrent transactions' DECIDEs
//! coalesce into shared doorbell trains with one persistence point per
//! group, and the same crash machinery proves all-or-nothing *per
//! group* (`rust/tests/group_commit.rs`).

use crate::fabric::sharded::ShardedFabric;
use crate::fabric::timing::{Nanos, TimingModel};
use crate::persist::config::ServerConfig;
use crate::persist::exec::{
    exec_compound, post_compound, post_compound_batch, post_singleton,
    post_singleton_batch, Update, WaitPoint,
};
use crate::persist::failover::{post_decision_replicated, witness_for};
use crate::persist::groupcommit::{
    post_decision_group, post_decision_group_replicated, GroupCommitOpts,
    GroupScheduler, PlannedGroup,
};
use crate::persist::method::{CompoundMethod, Primary, SingletonMethod};
use crate::persist::planner::{plan_compound, plan_singleton};
use crate::persist::txn::{
    plan_txn_method, post_commit, post_decision, post_prepare,
    recover_intents, roll_forward, sync_clock, CommitFlip, DecisionScan,
    IntentRecord, SlotRing, DECISION_BYTES, INTENT_BYTES,
};
use crate::remotelog::client::{
    AppendMode, AppendRecord, MethodChoice, RemoteLog,
};
use crate::remotelog::crashtest::{check_log_crash_at, CrashReport};
use crate::remotelog::log::{
    make_record, LogLayout, APP_WORDS, RECORD_BYTES,
};
use crate::remotelog::recovery::{recover, Scanner};
use crate::server::memory::Layout;
use crate::util::rng::{mix, SplitMix64, Zipf};
use crate::util::stats::Histogram;
use std::collections::VecDeque;

/// Result of a pipelined run.
#[derive(Debug, Clone)]
pub struct PipelineResult {
    /// Appends performed.
    pub appends: u64,
    /// Window depth the run used.
    pub window: usize,
    /// Virtual time from first post to last persistence point.
    pub span_ns: Nanos,
    /// Mean per-append latency (ns).
    pub mean_latency_ns: f64,
    /// p99 per-append latency (ns).
    pub p99_latency_ns: u64,
}

impl PipelineResult {
    /// Sustained append throughput in million ops per *simulated* second.
    pub fn throughput_mops(&self) -> f64 {
        self.appends as f64 / self.span_ns as f64 * 1e3
    }
}

/// Is a compound method a pure post-train (no internal completion
/// waits), i.e. windowable and doorbell-batchable?
pub fn compound_pipelinable(m: CompoundMethod) -> bool {
    !matches!(
        m,
        CompoundMethod::WriteMsgFlushAckTwice
            | CompoundMethod::WriteImmFlushAckTwice
            | CompoundMethod::WriteFlushWaitWriteFlush
            | CompoundMethod::WriteImmFlushWaitImmFlush
    )
}

/// Is the client's configured method a pure post-train (pipelinable)?
pub fn pipelinable(rl: &RemoteLog) -> bool {
    match rl.mode {
        AppendMode::Singleton => true, // all thirteen singleton methods are
        AppendMode::Compound => compound_pipelinable(rl.compound_method()),
    }
}

/// Deterministic per-seq payload used by the pipelined/batched/sharded
/// runners: content depends only on `seq`, so differently scheduled runs
/// (any window, batch, or shard count) produce byte-identical logs.
pub fn pipeline_payload(seq: u64) -> [u32; APP_WORDS] {
    let mut app = [0u32; APP_WORDS];
    for (k, w) in app.iter_mut().enumerate() {
        *w = (seq as u32).wrapping_mul(0x9E37_79B9) ^ k as u32;
    }
    app
}

/// Run `n` appends keeping up to `window` in flight. Falls back to
/// sequential execution (window = 1 semantics) for methods with internal
/// waits. Latencies are recorded into `rl.latencies` as usual.
pub fn run_pipelined(rl: &mut RemoteLog, n: u64, window: usize) -> PipelineResult {
    assert!(window >= 1);
    if !pipelinable(rl) || window == 1 {
        let t0 = rl.fab.now();
        rl.run(n);
        return PipelineResult {
            appends: n,
            window: 1,
            span_ns: rl.fab.now() - t0,
            mean_latency_ns: rl.latencies.summary().mean(),
            p99_latency_ns: rl.latencies.quantile(0.99),
        };
    }

    let t0 = rl.fab.now();
    let mut inflight: VecDeque<(u64, Nanos, WaitPoint, [u8; 64])> =
        VecDeque::with_capacity(window);
    let mut payload_seq = rl.appended();

    for _ in 0..n {
        // Window full: retire the oldest append first.
        if inflight.len() == window {
            retire(rl, &mut inflight);
        }
        let seq = payload_seq;
        payload_seq += 1;
        let record = make_record(seq, &pipeline_payload(seq));
        let slot = rl.log.slot_addr(seq);
        assert!(
            seq < rl.log.capacity || !rl.fab.mem.recording(),
            "log wraparound would invalidate the crash oracle"
        );
        let start = rl.fab.now();
        let singleton_method = rl.singleton_method();
        let compound_method = rl.compound_method();
        let wp = match rl.mode {
            AppendMode::Singleton => {
                let u = Update::new(slot, record.to_vec());
                post_singleton(&mut rl.fab, singleton_method, &u, seq as u32)
            }
            AppendMode::Compound => {
                let a = Update::new(slot, record.to_vec());
                let b = Update::new(
                    rl.log.tail_addr,
                    (seq + 1).to_le_bytes().to_vec(),
                );
                post_compound(&mut rl.fab, compound_method, &a, &b, seq as u32)
                    .expect("checked pipelinable above")
            }
        };
        inflight.push_back((seq, start, wp, record));
    }
    while !inflight.is_empty() {
        retire(rl, &mut inflight);
    }
    rl.bump_seq_to(payload_seq);

    PipelineResult {
        appends: n,
        window,
        span_ns: rl.fab.now() - t0,
        mean_latency_ns: rl.latencies.summary().mean(),
        p99_latency_ns: rl.latencies.quantile(0.99),
    }
}

fn retire(
    rl: &mut RemoteLog,
    inflight: &mut VecDeque<(u64, Nanos, WaitPoint, [u8; 64])>,
) {
    let (seq, start, wp, record) = inflight.pop_front().expect("non-empty");
    let acked = wp.wait(&mut rl.fab);
    rl.latencies.record(acked - start);
    if rl.fab.mem.recording() {
        rl.appends.push(AppendRecord { seq, record, acked_at: acked });
    }
}

/// One in-flight doorbell train: `records.len()` appends sharing one
/// wait-point; every append in the train is acked when it resolves.
struct BatchTrain {
    first_seq: u64,
    start: Nanos,
    wp: WaitPoint,
    records: Vec<[u8; RECORD_BYTES]>,
}

fn retire_batch(rl: &mut RemoteLog, inflight: &mut VecDeque<BatchTrain>) {
    let train = inflight.pop_front().expect("non-empty");
    let acked = train.wp.wait(&mut rl.fab);
    for (j, rec) in train.records.iter().enumerate() {
        rl.latencies.record(acked - train.start);
        if rl.fab.mem.recording() {
            rl.appends.push(AppendRecord {
                seq: train.first_seq + j as u64,
                record: *rec,
                acked_at: acked,
            });
        }
    }
}

/// Run `n` appends as doorbell trains of `batch` records with up to
/// `window` trains in flight. Each train is one submission with ONE
/// wait-point (see [`post_singleton_batch`]); every record in a train is
/// acked at the train's persistence point. Falls back to
/// [`run_pipelined`] for `batch == 1` or methods with internal waits.
pub fn run_batched(
    rl: &mut RemoteLog,
    n: u64,
    batch: usize,
    window: usize,
) -> PipelineResult {
    assert!(batch >= 1 && window >= 1);
    if !pipelinable(rl) || batch == 1 {
        return run_pipelined(rl, n, window);
    }
    let t0 = rl.fab.now();
    let mut inflight: VecDeque<BatchTrain> = VecDeque::with_capacity(window);
    let mut seq = rl.appended();
    let end_seq = seq + n;
    assert!(
        end_seq <= rl.log.capacity || !rl.fab.mem.recording(),
        "log wraparound would invalidate the crash oracle"
    );
    let singleton_method = rl.singleton_method();
    let compound_method = rl.compound_method();

    while seq < end_seq {
        if inflight.len() == window {
            retire_batch(rl, &mut inflight);
        }
        let len = batch.min((end_seq - seq) as usize);
        let start = rl.fab.now();
        let mut records = Vec::with_capacity(len);
        let wp = match rl.mode {
            AppendMode::Singleton => {
                let mut updates = Vec::with_capacity(len);
                for j in 0..len as u64 {
                    let s = seq + j;
                    let record = make_record(s, &pipeline_payload(s));
                    updates
                        .push(Update::new(rl.log.slot_addr(s), record.to_vec()));
                    records.push(record);
                }
                post_singleton_batch(
                    &mut rl.fab,
                    singleton_method,
                    &updates,
                    seq as u32,
                )
            }
            AppendMode::Compound => {
                let mut pairs = Vec::with_capacity(len);
                for j in 0..len as u64 {
                    let s = seq + j;
                    let record = make_record(s, &pipeline_payload(s));
                    pairs.push((
                        Update::new(rl.log.slot_addr(s), record.to_vec()),
                        Update::new(
                            rl.log.tail_addr,
                            (s + 1).to_le_bytes().to_vec(),
                        ),
                    ));
                    records.push(record);
                }
                post_compound_batch(
                    &mut rl.fab,
                    compound_method,
                    &pairs,
                    seq as u32,
                )
                .expect("checked pipelinable above")
            }
        };
        inflight.push_back(BatchTrain { first_seq: seq, start, wp, records });
        seq += len as u64;
    }
    while !inflight.is_empty() {
        retire_batch(rl, &mut inflight);
    }
    rl.bump_seq_to(seq);

    PipelineResult {
        appends: n,
        window,
        span_ns: rl.fab.now() - t0,
        mean_latency_ns: rl.latencies.summary().mean(),
        p99_latency_ns: rl.latencies.quantile(0.99),
    }
}

// ---------------------------------------------------------------------
// Multi-client sharded pipelines: M clients × window-W trains over an
// N-QP fabric — the throughput-scaling axis.
// ---------------------------------------------------------------------

/// Options for a multi-client sharded run.
#[derive(Debug, Clone)]
pub struct ShardedRunOpts {
    /// Number of independent append streams.
    pub clients: usize,
    /// Number of QPs; clients are assigned round-robin (client c → QP
    /// c % shards), so `shards == clients` gives every client its own
    /// connection and `shards < clients` shares QPs (serialization).
    pub shards: usize,
    /// Doorbell trains in flight per client.
    pub window: usize,
    /// Appends per doorbell train (single wait-point per train).
    pub batch: usize,
    /// Appends each client performs.
    pub appends_per_client: u64,
    /// Log slots per client (each client gets its own PM region).
    pub capacity: u64,
    /// Jitter seed.
    pub seed: u64,
    /// Record write timelines + oracles (required for crash sweeps).
    pub record: bool,
}

impl Default for ShardedRunOpts {
    fn default() -> Self {
        ShardedRunOpts {
            clients: 1,
            shards: 1,
            window: 8,
            batch: 1,
            appends_per_client: 1000,
            capacity: 8192,
            seed: 7,
            record: false,
        }
    }
}

/// One client of a sharded run: its QP, log region, and oracle history.
pub struct ShardedClient {
    /// QP this client's appends ride on.
    pub qp: usize,
    /// The client's log region on that QP's PM.
    pub log: LogLayout,
    /// Oracle history (populated only when recording).
    pub appends: Vec<AppendRecord>,
    /// Per-append latencies.
    pub latencies: Histogram,
}

impl ShardedClient {
    /// Number of this client's appends acked at or before `t`.
    pub fn acked_before(&self, t: Nanos) -> u64 {
        self.appends.iter().take_while(|a| a.acked_at <= t).count() as u64
    }
}

/// A completed multi-client sharded run (fabric + per-client oracles),
/// ready for crash sweeps.
pub struct ShardedRun {
    /// Which REMOTELOG variant ran.
    pub mode: AppendMode,
    /// The N-QP fabric the run executed on.
    pub fabric: ShardedFabric,
    /// Per-client regions + oracles.
    pub clients: Vec<ShardedClient>,
    singleton_method: SingletonMethod,
    compound_method: CompoundMethod,
}

impl ShardedRun {
    /// Assemble a completed run from parts. Crate-visible so alternate
    /// drivers (the reactor adapters in [`crate::runtime::reactor`]) can
    /// hand their fabrics to the unchanged crash machinery.
    pub(crate) fn assemble(
        mode: AppendMode,
        fabric: ShardedFabric,
        clients: Vec<ShardedClient>,
        singleton_method: SingletonMethod,
        compound_method: CompoundMethod,
    ) -> Self {
        ShardedRun { mode, fabric, clients, singleton_method, compound_method }
    }

    /// The singleton method the run used (singleton mode).
    pub fn singleton_method(&self) -> SingletonMethod {
        self.singleton_method
    }

    /// The compound method the run used (compound mode).
    pub fn compound_method(&self) -> CompoundMethod {
        self.compound_method
    }

    fn needs_replay(&self) -> bool {
        match self.mode {
            AppendMode::Singleton => self.singleton_method.requires_replay(),
            AppendMode::Compound => self.compound_method.requires_replay(),
        }
    }
}

/// Aggregate result of a multi-client sharded run.
#[derive(Debug, Clone)]
pub struct MultiClientResult {
    /// Client count.
    pub clients: usize,
    /// QP count.
    pub shards: usize,
    /// Effective window depth (1 for non-pipelinable methods).
    pub window: usize,
    /// Effective doorbell batch (1 for non-pipelinable methods).
    pub batch: usize,
    /// Total appends across all clients.
    pub appends: u64,
    /// Makespan: parallel virtual time from start to the last
    /// persistence point on any QP.
    pub span_ns: Nanos,
    /// Mean per-append latency (ns).
    pub mean_latency_ns: f64,
    /// p99 per-append latency (ns).
    pub p99_latency_ns: u64,
}

impl MultiClientResult {
    /// Aggregate throughput in million appends per simulated second.
    pub fn throughput_mops(&self) -> f64 {
        self.appends as f64 / self.span_ns as f64 * 1e3
    }
}

fn retire_client(
    fabric: &mut ShardedFabric,
    client: &mut ShardedClient,
    inflight: &mut VecDeque<BatchTrain>,
    summary: &mut Histogram,
    record: bool,
) {
    let train = inflight.pop_front().expect("non-empty");
    let acked = train.wp.wait(fabric.qp_mut(client.qp));
    for (j, rec) in train.records.iter().enumerate() {
        let lat = acked - train.start;
        client.latencies.record(lat);
        summary.record(lat);
        if record {
            client.appends.push(AppendRecord {
                seq: train.first_seq + j as u64,
                record: *rec,
                acked_at: acked,
            });
        }
    }
}

/// Drive `clients` append streams, each a window-W pipeline of
/// doorbell-batched trains, over an N-QP sharded fabric.
///
/// Clients co-located on one QP interleave their posts deterministically
/// (round-robin) and serialize on the shared connection; clients on
/// different QPs advance in parallel virtual time. Non-pipelinable
/// compound methods degrade to sequential execution (window = batch =
/// 1), exactly like [`run_pipelined`].
pub fn run_multi_client(
    cfg: ServerConfig,
    timing: TimingModel,
    mode: AppendMode,
    choice: MethodChoice,
    opts: &ShardedRunOpts,
) -> (ShardedRun, MultiClientResult) {
    assert!(opts.clients >= 1 && opts.shards >= 1);
    assert!(opts.window >= 1 && opts.batch >= 1);
    let (sm, cm) = match choice {
        MethodChoice::Planned(p) => {
            (plan_singleton(&cfg, p), plan_compound(&cfg, p, 8))
        }
        MethodChoice::ForcedSingleton(m) => {
            (m, plan_compound(&cfg, Primary::Write, 8))
        }
        MethodChoice::ForcedCompound(m) => {
            (plan_singleton(&cfg, Primary::Write), m)
        }
    };
    let pipelinable = match mode {
        AppendMode::Singleton => true,
        AppendMode::Compound => compound_pipelinable(cm),
    };
    let (window, batch) =
        if pipelinable { (opts.window, opts.batch) } else { (1, 1) };
    let total = opts.appends_per_client;
    assert!(
        !opts.record || total <= opts.capacity,
        "log wraparound would invalidate the crash oracle"
    );

    // Size each QP's PM for its co-located clients' log regions plus the
    // RQWRB ring (slots wide enough for batched wire envelopes).
    let clients_per_qp = opts.clients.div_ceil(opts.shards);
    let region = LogLayout::region_stride(opts.capacity);
    let rq_count = 64usize;
    let rq_slot = 8192u64;
    let pm_size = (region * clients_per_qp as u64
        + rq_count as u64 * rq_slot
        + 4096)
        .next_power_of_two();
    let layout = Layout::new(pm_size, pm_size / 2, rq_count, rq_slot, cfg.rqwrb);
    let mut fabric = ShardedFabric::new(
        cfg,
        timing,
        layout,
        opts.seed,
        opts.record,
        opts.shards,
    );

    let mut clients: Vec<ShardedClient> = (0..opts.clients)
        .map(|c| {
            let qp = c % opts.shards;
            let k = (c / opts.shards) as u64;
            let log = LogLayout::in_region(k * region, opts.capacity);
            assert!(
                log.end() <= fabric.qp(qp).mem.layout.pm_app_limit(),
                "client region overlaps the RQWRB ring"
            );
            ShardedClient {
                qp,
                log,
                appends: Vec::new(),
                latencies: Histogram::new(),
            }
        })
        .collect();

    let mut inflight: Vec<VecDeque<BatchTrain>> =
        (0..opts.clients).map(|_| VecDeque::new()).collect();
    let mut next_seq = vec![0u64; opts.clients];
    let mut summary = Histogram::new();

    // Round-robin issue loop: one train per client per pass.
    loop {
        let mut progressed = false;
        for c in 0..opts.clients {
            if next_seq[c] >= total {
                continue;
            }
            progressed = true;
            if inflight[c].len() == window {
                retire_client(
                    &mut fabric,
                    &mut clients[c],
                    &mut inflight[c],
                    &mut summary,
                    opts.record,
                );
            }
            let first = next_seq[c];
            let len = (batch as u64).min(total - first) as usize;
            let (qp, log) = (clients[c].qp, clients[c].log.clone());

            if mode == AppendMode::Compound && !pipelinable {
                // Internal-wait method: synchronous single append.
                let record = make_record(first, &pipeline_payload(first));
                let a = Update::new(log.slot_addr(first), record.to_vec());
                let b = Update::new(
                    log.tail_addr,
                    (first + 1).to_le_bytes().to_vec(),
                );
                let fab = fabric.qp_mut(qp);
                let out = exec_compound(fab, cm, &a, &b, first as u32);
                let lat = out.acked - out.start;
                clients[c].latencies.record(lat);
                summary.record(lat);
                if opts.record {
                    clients[c].appends.push(AppendRecord {
                        seq: first,
                        record,
                        acked_at: out.acked,
                    });
                }
                next_seq[c] += 1;
                continue;
            }

            let fab = fabric.qp_mut(qp);
            let start = fab.now();
            let mut records = Vec::with_capacity(len);
            let wp = match mode {
                AppendMode::Singleton => {
                    let mut updates = Vec::with_capacity(len);
                    for j in 0..len as u64 {
                        let s = first + j;
                        let record = make_record(s, &pipeline_payload(s));
                        updates.push(Update::new(
                            log.slot_addr(s),
                            record.to_vec(),
                        ));
                        records.push(record);
                    }
                    post_singleton_batch(fab, sm, &updates, first as u32)
                }
                AppendMode::Compound => {
                    let mut pairs = Vec::with_capacity(len);
                    for j in 0..len as u64 {
                        let s = first + j;
                        let record = make_record(s, &pipeline_payload(s));
                        pairs.push((
                            Update::new(log.slot_addr(s), record.to_vec()),
                            Update::new(
                                log.tail_addr,
                                (s + 1).to_le_bytes().to_vec(),
                            ),
                        ));
                        records.push(record);
                    }
                    post_compound_batch(fab, cm, &pairs, first as u32)
                        .expect("checked pipelinable above")
                }
            };
            inflight[c].push_back(BatchTrain {
                first_seq: first,
                start,
                wp,
                records,
            });
            next_seq[c] += len as u64;
        }
        if !progressed {
            break;
        }
    }
    for c in 0..opts.clients {
        while !inflight[c].is_empty() {
            retire_client(
                &mut fabric,
                &mut clients[c],
                &mut inflight[c],
                &mut summary,
                opts.record,
            );
        }
    }

    let span_ns = fabric.makespan();
    let result = MultiClientResult {
        clients: opts.clients,
        shards: opts.shards,
        window,
        batch,
        appends: total * opts.clients as u64,
        span_ns,
        mean_latency_ns: summary.summary().mean(),
        p99_latency_ns: summary.quantile(0.99),
    };
    let run = ShardedRun {
        mode,
        fabric,
        clients,
        singleton_method: sm,
        compound_method: cm,
    };
    (run, result)
}

/// Check one crash instant of a multi-client sharded run: every client's
/// log must uphold the durability/integrity/ordering contracts on its
/// own QP's crash image.
pub fn check_sharded_crash_at(
    run: &ShardedRun,
    t: Nanos,
    scanner: &dyn Scanner,
) -> CrashReport {
    let mut rep = CrashReport::default();
    for client in &run.clients {
        let fab = run.fabric.qp(client.qp);
        let image = fab.mem.crash_image(t, fab.cfg.pdomain);
        rep.merge(&check_log_crash_at(
            &image,
            &fab.mem.layout,
            &client.log,
            run.mode,
            run.needs_replay(),
            &client.appends,
            t,
            scanner,
        ));
    }
    rep.crash_points = 1;
    rep
}

/// Crash sweep over a completed sharded run: uniform global instants
/// plus the adversarial instants around every client's every ack.
pub fn sharded_crash_sweep(
    run: &ShardedRun,
    uniform_points: u64,
    seed: u64,
    scanner: &dyn Scanner,
) -> CrashReport {
    assert!(
        run.fabric.qp(0).mem.recording(),
        "crash sweep requires a recording run"
    );
    let end = run.fabric.makespan();
    let mut rng = SplitMix64::new(seed);
    let mut report = CrashReport::default();
    for _ in 0..uniform_points {
        let t = rng.next_below(end.max(1));
        report.merge(&check_sharded_crash_at(run, t, scanner));
    }
    for client in &run.clients {
        for a in &client.appends {
            for t in
                [a.acked_at, a.acked_at + 1, a.acked_at.saturating_sub(1)]
            {
                report.merge(&check_sharded_crash_at(run, t, scanner));
            }
        }
    }
    report.merge(&check_sharded_crash_at(run, end, scanner));
    report
}

// ---------------------------------------------------------------------
// Cross-shard transactional runner: every append is a transaction that
// spans EVERY shard (one record + tail flip per shard), committed with
// the persist::txn two-phase protocol — the first cross-connection
// correctness scenario, where per-QP ordering stops helping.
// ---------------------------------------------------------------------

/// Options for a multi-shard transactional run.
#[derive(Debug, Clone)]
pub struct TxnRunOpts {
    /// Independent coordinators; client `c`'s decision ring lives on QP
    /// `c % shards`.
    pub clients: usize,
    /// QPs; every transaction spans ALL of them.
    pub shards: usize,
    /// Transactions per client.
    pub txns_per_client: u64,
    /// Log slots (= intent/decision slots) per client per shard.
    pub capacity: u64,
    /// Jitter seed.
    pub seed: u64,
    /// Record write timelines + oracles (required for crash sweeps).
    pub record: bool,
    /// `true`: two-phase commit (atomic). `false`: independent per-shard
    /// compound appends — the negative control whose crash states are
    /// NOT all-or-nothing.
    pub atomic: bool,
    /// Mirror every decision record to the witness QP before acking
    /// ([`crate::persist::failover`]): the commit state then survives
    /// any single-shard loss. Requires `shards >= 2`; only meaningful
    /// with `atomic`.
    pub replicate: bool,
}

impl Default for TxnRunOpts {
    fn default() -> Self {
        TxnRunOpts {
            clients: 1,
            shards: 2,
            txns_per_client: 100,
            capacity: 256,
            seed: 7,
            record: false,
            atomic: true,
            replicate: false,
        }
    }
}

/// Oracle record of one transaction (recording runs only).
#[derive(Debug, Clone)]
pub struct TxnOracle {
    /// Transaction id (log slot / ring slot on every shard).
    pub txn_id: u64,
    /// The record appended to each shard's log, indexed by QP.
    pub records: Vec<[u8; RECORD_BYTES]>,
    /// When every shard's PREPARE persistence point was observed
    /// (atomic runs; equals `acked_at` for independent runs).
    pub prepared_at: Nanos,
    /// The decision record's persistence point (atomic runs) or the
    /// last per-shard append ack (independent runs).
    pub acked_at: Nanos,
}

/// One coordinator of a transactional run: its per-shard log regions,
/// intent rings, decision ring, and oracle history.
pub struct TxnClient {
    /// QP holding this client's decision ring.
    pub coord_qp: usize,
    /// QP holding this client's replica ring (replicated runs; equals
    /// `coord_qp` when the fabric has a single QP).
    pub witness_qp: usize,
    /// Per-QP log region.
    pub logs: Vec<LogLayout>,
    /// Per-QP intent ring.
    pub intents: Vec<SlotRing>,
    /// Decision ring (on `coord_qp`).
    pub decisions: SlotRing,
    /// Witness replica of the decision ring (on `witness_qp`).
    pub replicas: SlotRing,
    /// Oracle history (populated only when recording).
    pub txns: Vec<TxnOracle>,
    /// Per-transaction commit latencies.
    pub latencies: Histogram,
}

/// A completed transactional run, ready for crash sweeps.
pub struct TxnRun {
    /// The N-QP fabric the run executed on.
    pub fabric: ShardedFabric,
    /// Per-coordinator state.
    pub clients: Vec<TxnClient>,
    /// Whether the run used two-phase commit.
    pub atomic: bool,
    /// Whether decision records were mirrored to the witness QP.
    pub replicate: bool,
    pub(crate) method: SingletonMethod,
    pub(crate) compound_method: CompoundMethod,
}

impl TxnRun {
    /// The singleton method the 2PC phases used.
    pub fn txn_method(&self) -> SingletonMethod {
        self.method
    }

    /// The compound method independent-mode appends used.
    pub fn compound_method(&self) -> CompoundMethod {
        self.compound_method
    }
}

/// Aggregate result of a transactional run.
#[derive(Debug, Clone)]
pub struct TxnRunResult {
    /// Coordinators.
    pub clients: usize,
    /// QPs (every transaction spans all of them).
    pub shards: usize,
    /// Total transactions across all clients.
    pub txns: u64,
    /// Makespan in virtual ns.
    pub span_ns: Nanos,
    /// Mean commit latency (ns).
    pub mean_latency_ns: f64,
    /// p99 commit latency (ns).
    pub p99_latency_ns: u64,
    /// Total DECIDE-phase cost (virtual ns): for every transaction, the
    /// span from its observed PREPARE completion to its decision ack —
    /// the per-transaction decision-persistence cost group commit
    /// amortizes. Zero for independent (non-atomic) runs.
    pub decision_ns_total: u64,
}

impl TxnRunResult {
    /// Aggregate commit throughput in million transactions per
    /// simulated second.
    pub fn throughput_mtps(&self) -> f64 {
        self.txns as f64 / self.span_ns as f64 * 1e3
    }

    /// Amortized decision-persistence cost per transaction (ns).
    pub fn decision_ns_per_txn(&self) -> f64 {
        self.decision_ns_total as f64 / self.txns.max(1) as f64
    }
}

/// Deterministic per-(client, shard, txn) record payload.
pub(crate) fn txn_payload(client: u64, shard: u64, txn: u64) -> [u32; APP_WORDS] {
    let salt = mix(
        client.wrapping_mul(0x9E37_79B9)
            ^ shard.wrapping_mul(0xC0FF_EE11)
            ^ txn,
    );
    let mut app = [0u32; APP_WORDS];
    for (k, w) in app.iter_mut().enumerate() {
        *w = (salt as u32).wrapping_add(k as u32 * 0x85EB_CA6B);
    }
    app
}

/// Deterministic per-`(seed, client, txn_index)` zipfian key set: the
/// hot-key workload trace feeding the contention engine
/// ([`crate::persist::contention`]). The draw is a pure function of its
/// arguments — a transaction that aborts and retries re-draws the
/// **identical** key set (the retry contends for the same locks, as a
/// real re-execution would), and different clients' streams decorrelate
/// through the salt. Keys within one set are distinct: a duplicate
/// draw retries from the stream up to a bound, then falls back to a
/// deterministic linear probe over the rank space, so any
/// `keys_per_txn <= zipf.n()` yields a full set.
pub fn zipf_txn_keys(
    zipf: &Zipf,
    seed: u64,
    client: usize,
    txn_index: u64,
    keys_per_txn: usize,
) -> Vec<u64> {
    assert!(
        keys_per_txn as u64 <= zipf.n(),
        "transaction wants {keys_per_txn} distinct keys from a space of {}",
        zipf.n()
    );
    let mut rng = SplitMix64::new(mix(
        seed ^ (client as u64).wrapping_mul(0xC0AB_17E5)
            ^ txn_index.wrapping_mul(0x9E37_79B9),
    ));
    let mut keys: Vec<u64> = Vec::with_capacity(keys_per_txn);
    let mut redraws = 0usize;
    while keys.len() < keys_per_txn {
        let mut k = zipf.sample(&mut rng);
        if keys.contains(&k) {
            redraws += 1;
            if redraws <= 16 * keys_per_txn {
                continue;
            }
            // Bounded redraws exhausted (pathological skew): probe to
            // the next free rank deterministically.
            while keys.contains(&k) {
                k = (k + 1) % zipf.n();
            }
        }
        keys.push(k);
    }
    keys
}

/// Build the N-QP fabric and per-coordinator region maps shared by the
/// transactional runners ([`run_txn_multi_shard`], [`run_txn_grouped`]):
/// per client per QP, log ‖ intent ring; the decision ring and its
/// witness replica ride in the same stride (used only on the
/// coordinator/witness QP respectively).
pub(crate) fn txn_fabric_and_clients(
    cfg: ServerConfig,
    timing: TimingModel,
    clients: usize,
    shards: usize,
    capacity: u64,
    seed: u64,
    record: bool,
) -> (ShardedFabric, Vec<TxnClient>) {
    let log_stride = LogLayout::region_stride(capacity);
    let intent_bytes =
        (capacity * INTENT_BYTES as u64).next_multiple_of(0x1000);
    let decision_bytes =
        (capacity * DECISION_BYTES as u64).next_multiple_of(0x1000);
    let stride = log_stride + intent_bytes + 2 * decision_bytes;
    // Slots sized for the prepare envelope (record + intent + wire
    // header) — the widest message any txn phase sends.
    let (rq_count, rq_slot) = (64usize, 2048u64);
    let pm_size = (stride * clients as u64
        + 2 * rq_count as u64 * rq_slot
        + 4096)
        .next_power_of_two();
    let layout =
        Layout::new(pm_size, pm_size / 2, rq_count, rq_slot, cfg.rqwrb);
    let fabric = ShardedFabric::new(cfg, timing, layout, seed, record, shards);

    let clients: Vec<TxnClient> = (0..clients)
        .map(|c| {
            let base = c as u64 * stride;
            let logs: Vec<LogLayout> = (0..shards)
                .map(|_| LogLayout::in_region(base, capacity))
                .collect();
            let intents: Vec<SlotRing> = (0..shards)
                .map(|_| SlotRing {
                    base: base + log_stride,
                    slots: capacity,
                    stride: INTENT_BYTES as u64,
                })
                .collect();
            let decisions = SlotRing {
                base: base + log_stride + intent_bytes,
                slots: capacity,
                stride: DECISION_BYTES as u64,
            };
            let replicas = SlotRing {
                base: decisions.end(),
                slots: capacity,
                stride: DECISION_BYTES as u64,
            };
            assert!(
                replicas.end() <= fabric.qp(0).mem.layout.pm_app_limit(),
                "client region overlaps the RQWRB ring"
            );
            let coord_qp = c % shards;
            TxnClient {
                coord_qp,
                witness_qp: if shards >= 2 {
                    witness_for(coord_qp, shards)
                } else {
                    coord_qp
                },
                logs,
                intents,
                decisions,
                replicas,
                txns: Vec::new(),
                latencies: Histogram::new(),
            }
        })
        .collect();
    (fabric, clients)
}

/// Drive `clients` coordinators, each appending `txns_per_client`
/// transactions that span every shard of an N-QP fabric.
///
/// Atomic mode runs the [`crate::persist::txn`] protocol per
/// transaction: PREPARE (record + intent, one train per shard, all
/// shards in parallel virtual time) → DECIDE (decision record on the
/// coordinator QP; its persistence point is the commit latency) →
/// COMMIT (tail flips). Independent mode appends the same records as
/// per-shard compound updates with no protocol — acked when the last
/// shard acks, with nothing tying the shards together at a crash.
pub fn run_txn_multi_shard(
    cfg: ServerConfig,
    timing: TimingModel,
    primary: Primary,
    opts: &TxnRunOpts,
) -> (TxnRun, TxnRunResult) {
    assert!(opts.clients >= 1 && opts.shards >= 1);
    assert!(
        !opts.record || opts.txns_per_client <= opts.capacity,
        "ring wraparound would invalidate the crash oracle"
    );
    assert!(
        !opts.replicate || (opts.atomic && opts.shards >= 2),
        "decision replication needs 2PC and a second shard"
    );
    let method = plan_txn_method(&cfg, primary);
    let compound_method = plan_compound(&cfg, primary, 8);
    let (mut fabric, mut clients) = txn_fabric_and_clients(
        cfg,
        timing,
        opts.clients,
        opts.shards,
        opts.capacity,
        opts.seed,
        opts.record,
    );
    let mut decision_ns_total = 0u64;

    // Each round runs one transaction per client, PHASE-INTERLEAVED:
    // every client's PREPAREs post before any client waits, so
    // coordinators pipeline their round trips on shared QPs instead of
    // serializing whole transactions — the clients axis measures real
    // concurrency. Per-client protocol ordering is untouched: a client's
    // decision posts only after observing ITS prepare points, and its
    // commit markers only after its decision point.
    let mut msg_seq = 0u32;
    for txn in 0..opts.txns_per_client {
        // PREPARE (or, independent mode, the raw compound appends).
        let mut starts = vec![0u64; opts.clients];
        let mut recs: Vec<Vec<[u8; RECORD_BYTES]>> =
            Vec::with_capacity(opts.clients);
        let mut wpss: Vec<Vec<Option<WaitPoint>>> =
            Vec::with_capacity(opts.clients);
        for c in 0..opts.clients {
            let client = &clients[c];
            // A transaction cannot complete before its busiest
            // participant frees up: latency baseline is the max clock.
            starts[c] = (0..opts.shards)
                .map(|s| fabric.qp(s).now())
                .max()
                .unwrap_or(0);
            let mut records = Vec::with_capacity(opts.shards);
            let mut wps = Vec::with_capacity(opts.shards);
            for s in 0..opts.shards {
                let record =
                    make_record(txn, &txn_payload(c as u64, s as u64, txn));
                let a = Update::new(
                    client.logs[s].slot_addr(txn),
                    record.to_vec(),
                );
                records.push(record);
                msg_seq = msg_seq.wrapping_add(4);
                if opts.atomic {
                    let intent = IntentRecord {
                        txn_id: txn,
                        shard: s as u32,
                        flips: vec![CommitFlip {
                            addr: client.logs[s].tail_addr,
                            value: txn + 1,
                        }],
                    };
                    wps.push(Some(post_prepare(
                        fabric.qp_mut(s),
                        method,
                        std::slice::from_ref(&a),
                        &intent,
                        client.intents[s].addr(txn),
                        msg_seq,
                    )));
                } else {
                    let b = Update::new(
                        client.logs[s].tail_addr,
                        (txn + 1).to_le_bytes().to_vec(),
                    );
                    match post_compound(
                        fabric.qp_mut(s),
                        compound_method,
                        &a,
                        &b,
                        msg_seq,
                    ) {
                        Some(wp) => wps.push(Some(wp)),
                        None => {
                            // Internal-wait method: synchronous append.
                            exec_compound(
                                fabric.qp_mut(s),
                                compound_method,
                                &a,
                                &b,
                                msg_seq,
                            );
                            wps.push(None);
                        }
                    }
                }
            }
            recs.push(records);
            wpss.push(wps);
        }
        // Observe every client's PREPARE persistence points.
        let mut prepared = vec![0u64; opts.clients];
        for (c, wps) in wpss.iter().enumerate() {
            for (s, wp) in wps.iter().enumerate() {
                let t = match wp {
                    Some(wp) => wp.wait(fabric.qp_mut(s)),
                    None => fabric.qp(s).now(),
                };
                prepared[c] = prepared[c].max(t);
            }
        }

        // DECIDE: post every client's decision, then observe the points
        // (decisions on distinct coordinator QPs overlap). Replicated
        // runs mirror each record to the witness QP and ack at the max
        // of both persistence points ([`crate::persist::failover`]).
        let mut acked = prepared.clone();
        if opts.atomic {
            let mut dwps = Vec::with_capacity(opts.clients);
            for c in 0..opts.clients {
                let qp = clients[c].coord_qp;
                if opts.replicate {
                    let wq = clients[c].witness_qp;
                    let (cseq, wseq) =
                        (msg_seq.wrapping_add(1), msg_seq.wrapping_add(2));
                    msg_seq = msg_seq.wrapping_add(2);
                    let (coord, wit) = fabric.qp_pair_mut(qp, wq);
                    let pair = post_decision_replicated(
                        coord,
                        wit,
                        method,
                        txn,
                        clients[c].decisions.addr(txn),
                        clients[c].replicas.addr(txn),
                        prepared[c],
                        cseq,
                        wseq,
                    );
                    dwps.push((pair.primary, Some(pair.witness)));
                } else {
                    sync_clock(fabric.qp_mut(qp), prepared[c]);
                    msg_seq = msg_seq.wrapping_add(1);
                    dwps.push((
                        post_decision(
                            fabric.qp_mut(qp),
                            method,
                            txn,
                            clients[c].decisions.addr(txn),
                            msg_seq,
                        ),
                        None,
                    ));
                }
            }
            for (c, (wp, rep)) in dwps.iter().enumerate() {
                acked[c] = wp.wait(fabric.qp_mut(clients[c].coord_qp));
                if let Some(rep) = rep {
                    acked[c] = acked[c]
                        .max(rep.wait(fabric.qp_mut(clients[c].witness_qp)));
                }
                decision_ns_total += acked[c] - prepared[c];
            }
            // COMMIT: release the tail markers. Truly lazy — posted
            // after each client's decision point but never awaited
            // (recovery roll-forward heals in-flight markers).
            for c in 0..opts.clients {
                for s in 0..opts.shards {
                    sync_clock(fabric.qp_mut(s), acked[c]);
                    msg_seq = msg_seq.wrapping_add(1);
                    let flip = CommitFlip {
                        addr: clients[c].logs[s].tail_addr,
                        value: txn + 1,
                    };
                    let _ = post_commit(
                        fabric.qp_mut(s),
                        method,
                        std::slice::from_ref(&flip),
                        msg_seq,
                    );
                }
            }
        }

        for (c, records) in recs.into_iter().enumerate() {
            clients[c].latencies.record(acked[c] - starts[c]);
            if opts.record {
                clients[c].txns.push(TxnOracle {
                    txn_id: txn,
                    records,
                    prepared_at: prepared[c],
                    acked_at: acked[c],
                });
            }
        }
    }

    let span_ns = fabric.makespan();
    let mut summary = Histogram::new();
    for c in &clients {
        summary.merge(&c.latencies);
    }
    let result = TxnRunResult {
        clients: opts.clients,
        shards: opts.shards,
        txns: opts.txns_per_client * opts.clients as u64,
        span_ns,
        mean_latency_ns: summary.summary().mean(),
        p99_latency_ns: summary.quantile(0.99),
        decision_ns_total,
    };
    let run = TxnRun {
        fabric,
        clients,
        atomic: opts.atomic,
        replicate: opts.replicate,
        method,
        compound_method,
    };
    (run, result)
}

// ---------------------------------------------------------------------
// Group-commit runner: concurrent transactions' DECIDEs coalesced into
// shared doorbell trains with a single persistence point per group
// (persist::groupcommit) — the amortization axis.
// ---------------------------------------------------------------------

/// Options for a group-commit transactional run.
#[derive(Debug, Clone)]
pub struct GroupRunOpts {
    /// Independent coordinators; client `c`'s decision ring lives on QP
    /// `c % shards`.
    pub clients: usize,
    /// QPs; every transaction spans ALL of them.
    pub shards: usize,
    /// Transactions per client.
    pub txns_per_client: u64,
    /// Log slots (= intent/decision slots) per client per shard.
    pub capacity: u64,
    /// Jitter seed.
    pub seed: u64,
    /// Record write timelines + oracles (required for crash sweeps).
    pub record: bool,
    /// Mirror every group's decision train to the witness QP before
    /// acking ([`crate::persist::failover`]); ack = max of the two
    /// group points. Requires `shards >= 2`.
    pub replicate: bool,
    /// Group-commit policy knobs ([`crate::persist::groupcommit`]).
    pub group: GroupCommitOpts,
}

impl Default for GroupRunOpts {
    fn default() -> Self {
        GroupRunOpts {
            clients: 1,
            shards: 2,
            txns_per_client: 100,
            capacity: 256,
            seed: 7,
            record: false,
            replicate: false,
            group: GroupCommitOpts::default(),
        }
    }
}

/// Aggregate result of a group-commit run.
#[derive(Debug, Clone)]
pub struct GroupRunResult {
    /// Coordinators.
    pub clients: usize,
    /// QPs (every transaction spans all of them).
    pub shards: usize,
    /// Total transactions across all clients.
    pub txns: u64,
    /// Decision trains released across all clients.
    pub groups: u64,
    /// Makespan in virtual ns.
    pub span_ns: Nanos,
    /// Mean commit latency (ns).
    pub mean_latency_ns: f64,
    /// p99 commit latency (ns).
    pub p99_latency_ns: u64,
    /// Total DECIDE-phase cost (virtual ns): per group, the span from
    /// its scheduler release to its shared ack point — directly
    /// comparable to [`TxnRunResult::decision_ns_total`], which pays
    /// that span once per *transaction*.
    pub decision_ns_total: u64,
    /// Per client, the released groups in order as `(first txn, len)` —
    /// the boundaries every recovered committed prefix must land on.
    pub group_sizes: Vec<Vec<(u64, u32)>>,
}

impl GroupRunResult {
    /// Aggregate commit throughput in million transactions per
    /// simulated second.
    pub fn throughput_mtps(&self) -> f64 {
        self.txns as f64 / self.span_ns as f64 * 1e3
    }

    /// Amortized decision-persistence cost per transaction (ns) — the
    /// quantity group commit exists to shrink.
    pub fn decision_ns_per_txn(&self) -> f64 {
        self.decision_ns_total as f64 / self.txns.max(1) as f64
    }

    /// The committed-prefix boundaries group atomicity allows for
    /// client `c`: 0, then the running prefix sum of its group sizes.
    pub fn boundaries(&self, c: usize) -> Vec<u64> {
        let mut out = vec![0u64];
        for &(first, len) in &self.group_sizes[c] {
            debug_assert_eq!(first, *out.last().unwrap(), "gap in groups");
            out.push(first + len as u64);
        }
        out
    }
}

/// Check that every committed prefix recoverable from a grouped run
/// lands on a group boundary: for each client, scan the primary
/// decision ring — and, for replicated runs, the witness ring — on
/// crash images at each of `instants`, and assert the recovered prefix
/// is one of [`GroupRunResult::boundaries`]. The single checker behind
/// `benches/group.rs` and the `rust/tests/group_commit.rs` campaign,
/// so the whole-group contract cannot drift between them.
pub fn assert_group_boundaries(
    run: &TxnRun,
    res: &GroupRunResult,
    instants: &[Nanos],
) {
    use crate::persist::txn::recover_decisions;
    for (ci, client) in run.clients.iter().enumerate() {
        let bounds = res.boundaries(ci);
        for &t in instants {
            let mut rings = vec![(client.coord_qp, &client.decisions)];
            if run.replicate {
                rings.push((client.witness_qp, &client.replicas));
            }
            for (qp, ring) in rings {
                let pd = run.fabric.qp(qp).cfg.pdomain;
                let img = run.fabric.qp(qp).mem.crash_image(t, pd);
                let committed = recover_decisions(&img, ring);
                assert!(
                    bounds.contains(&committed),
                    "client {ci} qp {qp}: prefix {committed} off the \
                     group boundaries {bounds:?} at t={t}"
                );
            }
        }
    }
}

/// Drive `clients` coordinators through `txns_per_client` cross-shard
/// transactions with **group commit**: transactions proceed in waves of
/// up to `max_group` concurrent in-flight transactions per client —
/// every PREPARE train of the wave posts before any is awaited — and
/// each client's [`GroupScheduler`] coalesces the wave's DECIDEs into
/// doorbell-batched trains with **one shared persistence point per
/// group** ([`post_decision_group`]); every member transaction acks at
/// its group's point. COMMIT markers release lazily as one train per
/// group per shard.
///
/// With `group.max_group == 1` the schedule degenerates to exactly
/// [`run_txn_multi_shard`]'s atomic path — same posting order, same
/// message sequence numbers, same virtual-time evolution — asserted by
/// `rust/tests/group_commit.rs`.
///
/// The returned [`TxnRun`] feeds the unchanged crash machinery
/// ([`txn_crash_sweep`], [`run_failover_sweep`]): recovery is still the
/// plain committed-prefix scan, and the reverse-posted group trains
/// guarantee the recovered prefix always lands on a group boundary.
pub fn run_txn_grouped(
    cfg: ServerConfig,
    timing: TimingModel,
    primary: Primary,
    opts: &GroupRunOpts,
) -> (TxnRun, GroupRunResult) {
    assert!(opts.clients >= 1 && opts.shards >= 1);
    assert!(opts.group.max_group >= 1);
    assert!(
        !opts.record || opts.txns_per_client <= opts.capacity,
        "ring wraparound would invalidate the crash oracle"
    );
    assert!(
        opts.group.max_group as u64 <= opts.capacity,
        "a group must fit the decision ring"
    );
    assert!(
        !opts.replicate || opts.shards >= 2,
        "decision replication needs a second shard"
    );
    let method = plan_txn_method(&cfg, primary);
    let compound_method = plan_compound(&cfg, primary, 8);
    let (mut fabric, mut clients) = txn_fabric_and_clients(
        cfg,
        timing,
        opts.clients,
        opts.shards,
        opts.capacity,
        opts.seed,
        opts.record,
    );

    let total = opts.txns_per_client;
    let mut msg_seq = 0u32;
    let mut decision_ns_total = 0u64;
    let mut group_sizes: Vec<Vec<(u64, u32)>> = vec![Vec::new(); opts.clients];

    let mut wave_first = 0u64;
    while wave_first < total {
        let wave =
            (opts.group.max_group as u64).min(total - wave_first) as usize;

        // PREPARE the whole wave: every client's every transaction, all
        // trains posted before any wait — the in-flight concurrency the
        // scheduler collects DECIDEs from.
        let mut starts = vec![vec![0u64; wave]; opts.clients];
        let mut recs: Vec<Vec<Vec<[u8; RECORD_BYTES]>>> =
            vec![Vec::with_capacity(wave); opts.clients];
        let mut wpss: Vec<Vec<Vec<WaitPoint>>> =
            vec![Vec::with_capacity(wave); opts.clients];
        for w in 0..wave {
            let txn = wave_first + w as u64;
            for c in 0..opts.clients {
                let client = &clients[c];
                starts[c][w] = (0..opts.shards)
                    .map(|s| fabric.qp(s).now())
                    .max()
                    .unwrap_or(0);
                let mut records = Vec::with_capacity(opts.shards);
                let mut wps = Vec::with_capacity(opts.shards);
                for s in 0..opts.shards {
                    let record =
                        make_record(txn, &txn_payload(c as u64, s as u64, txn));
                    let a = Update::new(
                        client.logs[s].slot_addr(txn),
                        record.to_vec(),
                    );
                    records.push(record);
                    msg_seq = msg_seq.wrapping_add(4);
                    let intent = IntentRecord {
                        txn_id: txn,
                        shard: s as u32,
                        flips: vec![CommitFlip {
                            addr: client.logs[s].tail_addr,
                            value: txn + 1,
                        }],
                    };
                    wps.push(post_prepare(
                        fabric.qp_mut(s),
                        method,
                        std::slice::from_ref(&a),
                        &intent,
                        client.intents[s].addr(txn),
                        msg_seq,
                    ));
                }
                recs[c].push(records);
                wpss[c].push(wps);
            }
        }
        // Observe every PREPARE point: per-transaction readiness (the
        // DECIDE request times the scheduler sees).
        let mut prepared = vec![vec![0u64; wave]; opts.clients];
        for w in 0..wave {
            for c in 0..opts.clients {
                for (s, wp) in wpss[c][w].iter().enumerate() {
                    prepared[c][w] =
                        prepared[c][w].max(wp.wait(fabric.qp_mut(s)));
                }
            }
        }

        // Schedule: each coordinator's DECIDE requests, in transaction
        // order, through the group-commit policy.
        let mut groups: Vec<Vec<PlannedGroup>> =
            Vec::with_capacity(opts.clients);
        for c in 0..opts.clients {
            let mut sched = GroupScheduler::new(opts.group);
            let mut gs = Vec::new();
            for w in 0..wave {
                let txn = wave_first + w as u64;
                if let Some(g) = sched.offer(txn, prepared[c][w]) {
                    gs.push(g);
                }
            }
            if let Some(g) = sched.drain() {
                gs.push(g);
            }
            groups.push(gs);
        }

        // GROUP DECIDE: post every client's trains, then observe the
        // shared points (trains on distinct coordinator QPs overlap;
        // replicated runs post the witness mirror before waiting
        // either point).
        let mut dwps: Vec<Vec<(WaitPoint, Option<WaitPoint>)>> =
            Vec::with_capacity(opts.clients);
        for c in 0..opts.clients {
            let qp = clients[c].coord_qp;
            let mut v = Vec::with_capacity(groups[c].len());
            for g in &groups[c] {
                if opts.replicate {
                    let wq = clients[c].witness_qp;
                    let (cseq, wseq) =
                        (msg_seq.wrapping_add(1), msg_seq.wrapping_add(2));
                    msg_seq = msg_seq.wrapping_add(2);
                    let (coord, wit) = fabric.qp_pair_mut(qp, wq);
                    let pair = post_decision_group_replicated(
                        coord,
                        wit,
                        method,
                        g.first,
                        g.len,
                        &clients[c].decisions,
                        &clients[c].replicas,
                        g.release_at,
                        cseq,
                        wseq,
                    );
                    v.push((pair.primary, Some(pair.witness)));
                } else {
                    msg_seq = msg_seq.wrapping_add(1);
                    v.push((
                        post_decision_group(
                            fabric.qp_mut(qp),
                            method,
                            g.first,
                            g.len,
                            &clients[c].decisions,
                            g.release_at,
                            msg_seq,
                        ),
                        None,
                    ));
                }
            }
            dwps.push(v);
        }
        let mut gacks: Vec<Vec<Nanos>> = vec![Vec::new(); opts.clients];
        for c in 0..opts.clients {
            for (gi, g) in groups[c].iter().enumerate() {
                let (wp, rep) = dwps[c][gi];
                let mut t = wp.wait(fabric.qp_mut(clients[c].coord_qp));
                if let Some(rep) = rep {
                    t = t.max(rep.wait(fabric.qp_mut(clients[c].witness_qp)));
                }
                decision_ns_total += t - g.release_at;
                gacks[c].push(t);
            }
        }

        // GROUP COMMIT: one train of the whole group's markers per
        // shard, posted after the group's shared point (lazy, never
        // awaited — recovery roll-forward heals in-flight markers).
        for c in 0..opts.clients {
            for (gi, g) in groups[c].iter().enumerate() {
                for s in 0..opts.shards {
                    sync_clock(fabric.qp_mut(s), gacks[c][gi]);
                    msg_seq = msg_seq.wrapping_add(g.len as u32);
                    let flips: Vec<CommitFlip> = (0..g.len as u64)
                        .map(|k| CommitFlip {
                            addr: clients[c].logs[s].tail_addr,
                            value: g.first + k + 1,
                        })
                        .collect();
                    let _ = post_commit(
                        fabric.qp_mut(s),
                        method,
                        &flips,
                        msg_seq,
                    );
                }
            }
        }

        // Book-keeping: every member acks at its group's shared point.
        for c in 0..opts.clients {
            let mut acked = Vec::with_capacity(wave);
            for (gi, g) in groups[c].iter().enumerate() {
                group_sizes[c].push((g.first, g.len as u32));
                for _ in 0..g.len {
                    acked.push(gacks[c][gi]);
                }
            }
            debug_assert_eq!(acked.len(), wave);
            for (w, rec) in recs[c].drain(..).enumerate() {
                clients[c].latencies.record(acked[w] - starts[c][w]);
                if opts.record {
                    clients[c].txns.push(TxnOracle {
                        txn_id: wave_first + w as u64,
                        records: rec,
                        prepared_at: prepared[c][w],
                        acked_at: acked[w],
                    });
                }
            }
        }

        wave_first += wave as u64;
    }

    let span_ns = fabric.makespan();
    let mut summary = Histogram::new();
    for c in &clients {
        summary.merge(&c.latencies);
    }
    let result = GroupRunResult {
        clients: opts.clients,
        shards: opts.shards,
        txns: total * opts.clients as u64,
        groups: group_sizes.iter().map(|g| g.len() as u64).sum(),
        span_ns,
        mean_latency_ns: summary.summary().mean(),
        p99_latency_ns: summary.quantile(0.99),
        decision_ns_total,
        group_sizes,
    };
    let run = TxnRun {
        fabric,
        clients,
        atomic: true,
        replicate: opts.replicate,
        method,
        compound_method,
    };
    (run, result)
}

/// Aggregated result of a transactional crash sweep.
#[derive(Debug, Clone, Default)]
pub struct TxnCrashReport {
    /// Crash instants checked.
    pub crash_points: u64,
    /// Crashes where an acked transaction was missing on some shard.
    pub durability_violations: u64,
    /// Crashes where shards disagreed on the recovered transaction
    /// count — a transaction recovered on some shards but not others
    /// (the all-or-nothing breach 2PC exists to prevent).
    pub atomicity_violations: u64,
    /// Crashes where a recovered record didn't match the oracle.
    pub integrity_violations: u64,
}

impl TxnCrashReport {
    /// No violations of any contract?
    pub fn clean(&self) -> bool {
        self.durability_violations == 0
            && self.atomicity_violations == 0
            && self.integrity_violations == 0
    }

    /// Accumulate another report.
    pub fn merge(&mut self, other: &TxnCrashReport) {
        self.crash_points += other.crash_points;
        self.durability_violations += other.durability_violations;
        self.atomicity_violations += other.atomicity_violations;
        self.integrity_violations += other.integrity_violations;
    }
}

/// Check one crash instant of a transactional run: per client, resolve
/// the committed set (presumed abort) and verify durability (acked ⇒
/// recovered), atomicity (every shard recovers the same transaction
/// prefix), and integrity (recovered records match the oracle).
pub fn check_txn_crash_at(
    run: &TxnRun,
    t: Nanos,
    scanner: &dyn Scanner,
) -> TxnCrashReport {
    check_txn_crash_at_with_loss(run, t, None, scanner)
}

/// [`check_txn_crash_at`] with the shard-loss fault: the power failure
/// at `t` additionally destroys shard `failed`'s PM outright (blank
/// image — see [`crate::server::memory::MemoryModel::failed_image`]).
///
/// The committed prefix is resolved from whatever decision state
/// survives: the merge of primary + witness rings for replicated runs
/// ([`crate::persist::failover::recover_decisions_merged`]; a blank
/// ring contributes nothing), the primary ring alone otherwise.
/// The durability / atomicity / integrity
/// contracts are then checked over the **surviving** shards — losing a
/// shard's payload is expected media loss; losing another shard's acked
/// transactions (because the decision died with the coordinator) is the
/// violation this mode exists to expose.
pub fn check_txn_crash_at_with_loss(
    run: &TxnRun,
    t: Nanos,
    failed: Option<usize>,
    scanner: &dyn Scanner,
) -> TxnCrashReport {
    let mut scans = vec![DecisionScan::default(); run.clients.len()];
    check_txn_crash_at_scanned(run, t, failed, scanner, &mut scans)
}

/// [`check_txn_crash_at_with_loss`] with caller-owned committed-prefix
/// scanners, one per client ([`DecisionScan`]). A sweep that visits its
/// crash instants in **ascending order** passes the same scanners to
/// every call: the committed prefix is monotone in the crash time on a
/// recording run, so each call resumes from the cached high-water mark
/// and the whole sweep makes a single pass over every decision ring
/// (instead of re-walking the full prefix at each of the hundreds of
/// instants). The cache is per (run, loss-mode): use fresh scanners
/// when either changes.
pub fn check_txn_crash_at_scanned(
    run: &TxnRun,
    t: Nanos,
    failed: Option<usize>,
    scanner: &dyn Scanner,
    scans: &mut [DecisionScan],
) -> TxnCrashReport {
    assert_eq!(scans.len(), run.clients.len(), "one scanner per client");
    let mut rep = TxnCrashReport { crash_points: 1, ..Default::default() };
    // One crash image per QP (images are per-QP, not per-client: client
    // regions are disjoint slices of the same PM). The lost shard
    // presents a blank image to every reader below.
    let shards = run.fabric.shards();
    let mut images: Vec<_> = (0..shards)
        .map(|s| {
            let fab = run.fabric.qp(s);
            if failed == Some(s) {
                fab.mem.failed_image()
            } else {
                fab.mem.crash_image(t, fab.cfg.pdomain)
            }
        })
        .collect();
    // Resolve every client's committed prefix BEFORE any roll-forward
    // patches (patches only touch tail words inside log regions, which
    // never overlap a decision ring — but reading first costs nothing).
    let committed: Vec<u64> = run
        .clients
        .iter()
        .zip(scans.iter_mut())
        .map(|(c, scan)| {
            if !run.atomic {
                0 // no protocol, nothing to resolve
            } else if run.replicate {
                scan.committed_merged(
                    Some((&images[c.coord_qp], &c.decisions)),
                    Some((&images[c.witness_qp], &c.replicas)),
                )
            } else {
                scan.committed(&images[c.coord_qp], &c.decisions)
            }
        })
        .collect();
    if run.atomic {
        for (ci, client) in run.clients.iter().enumerate() {
            for s in 0..shards {
                if failed == Some(s) {
                    continue; // lost media: nothing to roll forward onto
                }
                let flips = recover_intents(
                    &images[s],
                    &client.intents[s],
                    s as u32,
                    committed[ci],
                );
                roll_forward(&mut images[s], &flips);
            }
        }
    }
    // The independent control keeps the planner's compound method
    // verbatim, which may be replay-class (one-sided SEND); atomic runs
    // never are (plan_txn_method substitutes apply-in-place methods).
    let replay = !run.atomic && run.compound_method.requires_replay();
    for client in &run.clients {
        let acked =
            client.txns.iter().take_while(|x| x.acked_at <= t).count() as u64;
        let mut recovered = Vec::with_capacity(client.logs.len());
        for (s, log) in client.logs.iter().enumerate() {
            if failed == Some(s) {
                continue;
            }
            recovered.push((
                s,
                recover(
                    &images[s],
                    &run.fabric.qp(s).mem.layout,
                    log,
                    AppendMode::Compound,
                    replay,
                    scanner,
                ),
            ));
        }
        if recovered.iter().any(|(_, r)| r.recovered < acked) {
            rep.durability_violations += 1;
        }
        if let Some((_, first)) = recovered.first() {
            let n0 = first.recovered;
            if recovered.iter().any(|(_, r)| r.recovered != n0) {
                rep.atomicity_violations += 1;
            }
        }
        for (s, r) in &recovered {
            let n = (r.recovered as usize).min(client.txns.len());
            for k in 0..n {
                let got = &r.records[k * RECORD_BYTES..(k + 1) * RECORD_BYTES];
                if got != &client.txns[k].records[*s][..] {
                    rep.integrity_violations += 1;
                }
            }
            if r.recovered as usize > client.txns.len() {
                rep.integrity_violations += 1;
            }
        }
    }
    rep
}

/// The crash schedule of a transactional sweep: `uniform_points` seeded
/// uniform instants plus the adversarial instants around every
/// transaction's PREPARE completion and ack (where in-doubt windows
/// open and close), plus the makespan — **sorted ascending** so the
/// sweep can reuse cached committed-prefix scanners
/// ([`check_txn_crash_at_scanned`]).
pub(crate) fn sweep_instants(
    run: &TxnRun,
    uniform_points: u64,
    seed: u64,
) -> Vec<Nanos> {
    let end = run.fabric.makespan();
    let mut rng = SplitMix64::new(seed);
    let mut instants: Vec<Nanos> = (0..uniform_points)
        .map(|_| rng.next_below(end.max(1)))
        .collect();
    for client in &run.clients {
        for x in &client.txns {
            instants.extend([
                x.prepared_at,
                x.prepared_at + 1,
                x.acked_at.saturating_sub(1),
                x.acked_at,
                x.acked_at + 1,
            ]);
        }
    }
    instants.push(end);
    instants.sort_unstable();
    instants
}

/// Crash sweep over a transactional run: uniform instants plus the
/// adversarial instants around every transaction's PREPARE completion
/// and ack (where in-doubt windows open and close). Instants are
/// visited in ascending order with per-client cached prefix scanners,
/// so the whole sweep is a single pass over each decision ring.
pub fn txn_crash_sweep(
    run: &TxnRun,
    uniform_points: u64,
    seed: u64,
    scanner: &dyn Scanner,
) -> TxnCrashReport {
    assert!(
        run.fabric.qp(0).mem.recording(),
        "crash sweep requires a recording run"
    );
    let mut scans = vec![DecisionScan::default(); run.clients.len()];
    let mut report = TxnCrashReport::default();
    for t in sweep_instants(run, uniform_points, seed) {
        report.merge(&check_txn_crash_at_scanned(
            run, t, None, scanner, &mut scans,
        ));
    }
    report
}

/// The failover campaign: the crash × shard-loss cross product. Every
/// instant of a [`txn_crash_sweep`]-style schedule (uniform points plus
/// the adversarial instants around each transaction's PREPARE completion
/// and ack) is checked under every loss mode — no shard lost, then each
/// shard lost in turn ([`check_txn_crash_at_with_loss`]).
///
/// For a replicated run the merged report must be clean: no committed
/// transaction lost, no aborted one resurrected, under any single-shard
/// loss at any instant. Run it on an unreplicated run to quantify the
/// gap instead (the coordinator-loss slice reports durability
/// violations for in-doubt decisions).
pub fn run_failover_sweep(
    run: &TxnRun,
    uniform_points: u64,
    seed: u64,
    scanner: &dyn Scanner,
) -> TxnCrashReport {
    assert!(
        run.fabric.qp(0).mem.recording(),
        "crash sweep requires a recording run"
    );
    let shards = run.fabric.shards();
    let loss_modes: Vec<Option<usize>> =
        std::iter::once(None).chain((0..shards).map(Some)).collect();
    let instants = sweep_instants(run, uniform_points, seed);
    let mut report = TxnCrashReport::default();
    // Loss mode outer, ascending instants inner: each mode gets its own
    // cached scanners (the surviving ring set differs per mode), and
    // within a mode the committed prefix is monotone, so every decision
    // ring is walked once per loss mode.
    for &failed in &loss_modes {
        let mut scans = vec![DecisionScan::default(); run.clients.len()];
        for &t in &instants {
            let rep =
                check_txn_crash_at_scanned(run, t, failed, scanner, &mut scans);
            report.merge(&rep);
        }
    }
    report
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fabric::timing::TimingModel;
    use crate::persist::config::{PDomain, RqwrbLoc, ServerConfig};
    use crate::persist::method::Primary;
    use crate::remotelog::client::MethodChoice;
    use crate::remotelog::crashtest::crash_sweep;
    use crate::remotelog::recovery::RustScanner;

    fn loss_at(run: &TxnRun, t: Nanos, failed: usize) -> TxnCrashReport {
        check_txn_crash_at_with_loss(run, t, Some(failed), &RustScanner)
    }

    fn client(mode: AppendMode, cfg: ServerConfig, record: bool) -> RemoteLog {
        RemoteLog::new(
            cfg,
            TimingModel::default(),
            mode,
            MethodChoice::Planned(Primary::Write),
            4096,
            5,
            record,
        )
    }

    #[test]
    fn deeper_windows_increase_throughput() {
        let cfg = ServerConfig::new(PDomain::Mhp, false, RqwrbLoc::Dram);
        let mut last = 0.0;
        for window in [1usize, 2, 8, 32] {
            let mut rl = client(AppendMode::Singleton, cfg, false);
            let res = run_pipelined(&mut rl, 3000, window);
            assert!(
                res.throughput_mops() > last,
                "window {window}: {} <= {last}",
                res.throughput_mops()
            );
            last = res.throughput_mops();
        }
        // Deep pipelining should beat sequential by a wide margin.
        assert!(last > 1.0, "expected >1 Mops at window 32, got {last}");
    }

    #[test]
    fn latency_grows_modestly_under_pipelining() {
        let cfg = ServerConfig::new(PDomain::Wsp, false, RqwrbLoc::Dram);
        let mut seq = client(AppendMode::Singleton, cfg, false);
        let s = run_pipelined(&mut seq, 2000, 1);
        let mut pipe = client(AppendMode::Singleton, cfg, false);
        let p = run_pipelined(&mut pipe, 2000, 16);
        assert!(p.throughput_mops() > 4.0 * s.throughput_mops());
        // Per-append latency may rise (queueing) but not explode.
        assert!(p.mean_latency_ns < 20.0 * s.mean_latency_ns);
    }

    #[test]
    fn pipelined_compound_methods_detected() {
        let dmp_ddio = ServerConfig::new(PDomain::Dmp, true, RqwrbLoc::Dram);
        let rl = client(AppendMode::Compound, dmp_ddio, false);
        // 2x message round trips — not pipelinable.
        assert!(!pipelinable(&rl));
        let mhp = ServerConfig::new(PDomain::Mhp, false, RqwrbLoc::Dram);
        let rl = client(AppendMode::Compound, mhp, false);
        assert!(pipelinable(&rl));
    }

    #[test]
    fn pipelined_runs_survive_crashes() {
        for cfg in [
            ServerConfig::new(PDomain::Dmp, false, RqwrbLoc::Dram),
            ServerConfig::new(PDomain::Mhp, true, RqwrbLoc::Dram),
            ServerConfig::new(PDomain::Wsp, false, RqwrbLoc::Pm),
        ] {
            for mode in [AppendMode::Singleton, AppendMode::Compound] {
                let mut rl = RemoteLog::new(
                    cfg,
                    TimingModel::default(),
                    mode,
                    MethodChoice::Planned(Primary::Write),
                    64,
                    9,
                    true,
                );
                run_pipelined(&mut rl, 40, 8);
                let rep = crash_sweep(&rl, 80, 3, &RustScanner);
                assert!(
                    rep.clean(),
                    "{} {} pipelined: {rep:?}",
                    cfg.label(),
                    mode.name()
                );
            }
        }
    }

    #[test]
    fn sequence_continues_after_pipelined_run() {
        let cfg = ServerConfig::new(PDomain::Wsp, false, RqwrbLoc::Dram);
        let mut rl = client(AppendMode::Singleton, cfg, false);
        run_pipelined(&mut rl, 100, 8);
        assert_eq!(rl.appended(), 100);
        rl.append();
        assert_eq!(rl.appended(), 101);
    }

    #[test]
    fn batched_trains_beat_unbatched_pipelining() {
        let cfg = ServerConfig::new(PDomain::Mhp, false, RqwrbLoc::Dram);
        let mut plain = client(AppendMode::Singleton, cfg, false);
        let p = run_pipelined(&mut plain, 3000, 8);
        let mut batched = client(AppendMode::Singleton, cfg, false);
        let b = run_batched(&mut batched, 3000, 8, 8);
        assert!(
            b.throughput_mops() > p.throughput_mops(),
            "batched {} <= pipelined {}",
            b.throughput_mops(),
            p.throughput_mops()
        );
        assert_eq!(batched.appended(), 3000);
    }

    #[test]
    fn multi_client_scaling_is_monotone() {
        let cfg = ServerConfig::new(PDomain::Mhp, false, RqwrbLoc::Dram);
        let mut last = 0.0;
        for m in [1usize, 2, 4, 8] {
            let opts = ShardedRunOpts {
                clients: m,
                shards: m,
                window: 8,
                batch: 4,
                appends_per_client: 400,
                capacity: 512,
                seed: 3,
                record: false,
            };
            let (_, res) = run_multi_client(
                cfg,
                TimingModel::default(),
                AppendMode::Singleton,
                MethodChoice::Planned(Primary::Write),
                &opts,
            );
            assert!(
                res.throughput_mops() >= last,
                "clients {m}: {} < {last}",
                res.throughput_mops()
            );
            last = res.throughput_mops();
        }
    }

    #[test]
    fn sharding_relieves_responder_cpu_bottleneck() {
        // Two-sided methods serialize on the responder CPU, so 4 clients
        // crammed onto 1 QP (one responder) are CPU-bound; spread over 4
        // QPs they get 4 responder CPUs and overlap.
        let cfg = ServerConfig::new(PDomain::Dmp, true, RqwrbLoc::Dram);
        let mut spans = Vec::new();
        for shards in [1usize, 4] {
            let opts = ShardedRunOpts {
                clients: 4,
                shards,
                window: 4,
                batch: 2,
                appends_per_client: 300,
                capacity: 512,
                seed: 5,
                record: false,
            };
            let (run, res) = run_multi_client(
                cfg,
                TimingModel::default(),
                AppendMode::Singleton,
                MethodChoice::Planned(Primary::Send),
                &opts,
            );
            assert_eq!(
                run.singleton_method(),
                crate::persist::method::SingletonMethod::SendCopyFlushAck
            );
            spans.push(res.span_ns);
        }
        assert!(
            spans[1] * 2 < spans[0],
            "4 QPs ({}) should be >2x faster than 1 QP ({})",
            spans[1],
            spans[0]
        );
    }

    #[test]
    fn txn_runner_atomic_survives_crashes() {
        for (cfg, primary) in [
            (
                ServerConfig::new(PDomain::Dmp, false, RqwrbLoc::Dram),
                Primary::Write,
            ),
            (
                ServerConfig::new(PDomain::Dmp, true, RqwrbLoc::Dram),
                Primary::Send,
            ),
            (
                ServerConfig::new(PDomain::Wsp, false, RqwrbLoc::Dram),
                Primary::Write,
            ),
        ] {
            let opts = TxnRunOpts {
                clients: 2,
                shards: 3,
                txns_per_client: 10,
                capacity: 32,
                seed: 13,
                record: true,
                atomic: true,
                replicate: false,
            };
            let (run, res) = run_txn_multi_shard(
                cfg,
                TimingModel::default(),
                primary,
                &opts,
            );
            assert_eq!(res.txns, 20);
            let rep = txn_crash_sweep(&run, 60, 5, &RustScanner);
            assert!(rep.clean(), "{} txn sweep: {rep:?}", cfg.label());
        }
    }

    #[test]
    fn independent_multi_shard_appends_are_not_atomic() {
        // The negative control: the same workload WITHOUT the commit
        // protocol must exhibit crash states where shards disagree —
        // the gap persist::txn exists to close. Per-shard durability
        // still holds (each connection's method is correct in
        // isolation); atomicity is what breaks.
        let cfg = ServerConfig::new(PDomain::Mhp, false, RqwrbLoc::Dram);
        let opts = TxnRunOpts {
            clients: 1,
            shards: 2,
            txns_per_client: 30,
            capacity: 64,
            seed: 17,
            record: true,
            atomic: false,
            replicate: false,
        };
        let (run, _) = run_txn_multi_shard(
            cfg,
            TimingModel::default(),
            Primary::Write,
            &opts,
        );
        let rep = txn_crash_sweep(&run, 500, 9, &RustScanner);
        assert_eq!(rep.durability_violations, 0, "{rep:?}");
        assert!(
            rep.atomicity_violations > 0,
            "independent appends should tear across shards: {rep:?}"
        );
    }

    #[test]
    fn txn_runs_are_deterministic() {
        let cfg = ServerConfig::new(PDomain::Mhp, false, RqwrbLoc::Dram);
        let opts = TxnRunOpts {
            clients: 2,
            shards: 2,
            txns_per_client: 50,
            capacity: 64,
            seed: 3,
            record: false,
            atomic: true,
            replicate: false,
        };
        let (_, a) = run_txn_multi_shard(
            cfg,
            TimingModel::default(),
            Primary::Write,
            &opts,
        );
        let (_, b) = run_txn_multi_shard(
            cfg,
            TimingModel::default(),
            Primary::Write,
            &opts,
        );
        assert_eq!(a.span_ns, b.span_ns);
        assert!(a.throughput_mtps() > 0.0);
        assert!(a.mean_latency_ns > 0.0);
    }

    #[test]
    fn txn_commit_costs_more_than_independent() {
        // 2PC buys atomicity with an extra decision round trip: the
        // atomic run must be slower, but not absurdly so.
        let cfg = ServerConfig::new(PDomain::Dmp, false, RqwrbLoc::Dram);
        let mk = |atomic| TxnRunOpts {
            clients: 1,
            shards: 4,
            txns_per_client: 60,
            capacity: 64,
            seed: 21,
            record: false,
            atomic,
            replicate: false,
        };
        let (_, atomic) = run_txn_multi_shard(
            cfg,
            TimingModel::default(),
            Primary::Write,
            &mk(true),
        );
        let (_, indep) = run_txn_multi_shard(
            cfg,
            TimingModel::default(),
            Primary::Write,
            &mk(false),
        );
        assert!(
            atomic.span_ns > indep.span_ns,
            "2PC {} should cost more than independent {}",
            atomic.span_ns,
            indep.span_ns
        );
        assert!(
            atomic.span_ns < indep.span_ns * 4,
            "2PC overhead should be bounded: {} vs {}",
            atomic.span_ns,
            indep.span_ns
        );
    }

    #[test]
    fn replicated_runner_survives_the_loss_cross_product() {
        let cfg = ServerConfig::new(PDomain::Mhp, false, RqwrbLoc::Dram);
        let opts = TxnRunOpts {
            clients: 2,
            shards: 3,
            txns_per_client: 6,
            capacity: 16,
            seed: 19,
            record: true,
            atomic: true,
            replicate: true,
        };
        let (run, _) = run_txn_multi_shard(
            cfg,
            TimingModel::default(),
            Primary::Write,
            &opts,
        );
        assert!(run.replicate);
        let rep = run_failover_sweep(&run, 20, 5, &RustScanner);
        assert!(rep.clean(), "replicated sweep: {rep:?}");
        // (no-loss + 3 loss modes) × every instant.
        assert!(rep.crash_points >= 4 * 20);
    }

    #[test]
    fn unreplicated_coordinator_loss_drops_in_doubt_decisions() {
        let cfg = ServerConfig::new(PDomain::Mhp, false, RqwrbLoc::Dram);
        let opts = TxnRunOpts {
            clients: 1,
            shards: 2,
            txns_per_client: 8,
            capacity: 16,
            seed: 23,
            record: true,
            atomic: true,
            replicate: false,
        };
        let (run, _) = run_txn_multi_shard(
            cfg,
            TimingModel::default(),
            Primary::Write,
            &opts,
        );
        let coord = run.clients[0].coord_qp;
        let mut coord_loss = TxnCrashReport::default();
        let mut other_loss = TxnCrashReport::default();
        for x in &run.clients[0].txns {
            // At the ack instant the lazy commit markers are still in
            // flight: the decision record alone commits the txn.
            for t in [x.acked_at, x.acked_at + 1] {
                let rep = loss_at(&run, t, coord);
                coord_loss.merge(&rep);
                let rep = loss_at(&run, t, 1 - coord);
                other_loss.merge(&rep);
            }
        }
        assert!(
            coord_loss.durability_violations > 0,
            "losing the unreplicated coordinator must lose acked txns: \
             {coord_loss:?}"
        );
        assert!(
            other_loss.clean(),
            "losing a participant shard keeps the decision ring: \
             {other_loss:?}"
        );
    }

    #[test]
    #[should_panic(expected = "second shard")]
    fn replication_requires_two_shards() {
        let cfg = ServerConfig::new(PDomain::Mhp, false, RqwrbLoc::Dram);
        let opts = TxnRunOpts {
            shards: 1,
            replicate: true,
            ..Default::default()
        };
        let _ = run_txn_multi_shard(
            cfg,
            TimingModel::default(),
            Primary::Write,
            &opts,
        );
    }

    #[test]
    fn grouped_runner_amortizes_decision_cost() {
        let cfg = ServerConfig::new(PDomain::Mhp, false, RqwrbLoc::Dram);
        let mk = |max_group| GroupRunOpts {
            clients: 2,
            shards: 2,
            txns_per_client: 64,
            capacity: 64,
            seed: 11,
            record: false,
            replicate: false,
            // Generous hold: max_group is the binding policy here.
            group: GroupCommitOpts {
                max_group,
                max_hold_ns: 1_000_000,
                idle_close: true,
            },
        };
        let (_, g1) = run_txn_grouped(
            cfg,
            TimingModel::default(),
            Primary::Write,
            &mk(1),
        );
        let (_, g8) = run_txn_grouped(
            cfg,
            TimingModel::default(),
            Primary::Write,
            &mk(8),
        );
        assert_eq!(g1.groups, 128, "unit groups: one train per txn");
        assert_eq!(g8.groups, 16, "64 txns / 8 per group x 2 clients");
        assert!(
            g8.decision_ns_per_txn() < g1.decision_ns_per_txn() / 2.0,
            "grouping 8 decisions must amortize: {} vs {}",
            g8.decision_ns_per_txn(),
            g1.decision_ns_per_txn()
        );
        assert!(
            g8.throughput_mtps() > g1.throughput_mtps(),
            "group commit must raise commit throughput: {} vs {}",
            g8.throughput_mtps(),
            g1.throughput_mtps()
        );
    }

    #[test]
    fn grouped_runner_survives_crashes_and_losses() {
        let cfg = ServerConfig::new(PDomain::Dmp, false, RqwrbLoc::Dram);
        for replicate in [false, true] {
            let opts = GroupRunOpts {
                clients: 2,
                shards: 3,
                txns_per_client: 8,
                capacity: 32,
                seed: 17,
                record: true,
                replicate,
                group: GroupCommitOpts { max_group: 4, ..Default::default() },
            };
            let (run, res) = run_txn_grouped(
                cfg,
                TimingModel::default(),
                Primary::Write,
                &opts,
            );
            assert_eq!(res.txns, 16);
            let rep = if replicate {
                run_failover_sweep(&run, 40, 5, &RustScanner)
            } else {
                txn_crash_sweep(&run, 40, 5, &RustScanner)
            };
            assert!(rep.clean(), "replicate={replicate}: {rep:?}");
        }
    }

    #[test]
    fn grouped_runs_are_deterministic() {
        let cfg = ServerConfig::new(PDomain::Wsp, false, RqwrbLoc::Dram);
        let opts = GroupRunOpts {
            clients: 2,
            shards: 2,
            txns_per_client: 40,
            capacity: 64,
            seed: 9,
            record: false,
            replicate: false,
            group: GroupCommitOpts::default(),
        };
        let (_, a) = run_txn_grouped(
            cfg,
            TimingModel::default(),
            Primary::Write,
            &opts,
        );
        let (_, b) = run_txn_grouped(
            cfg,
            TimingModel::default(),
            Primary::Write,
            &opts,
        );
        assert_eq!(a.span_ns, b.span_ns);
        assert_eq!(a.decision_ns_total, b.decision_ns_total);
        assert_eq!(a.group_sizes, b.group_sizes);
    }

    #[test]
    fn sweep_schedules_are_sorted_for_the_scan_cache() {
        let cfg = ServerConfig::new(PDomain::Mhp, false, RqwrbLoc::Dram);
        let opts = TxnRunOpts {
            clients: 2,
            shards: 2,
            txns_per_client: 6,
            capacity: 16,
            seed: 3,
            record: true,
            atomic: true,
            replicate: false,
        };
        let (run, _) = run_txn_multi_shard(
            cfg,
            TimingModel::default(),
            Primary::Write,
            &opts,
        );
        let instants = sweep_instants(&run, 30, 7);
        assert!(instants.windows(2).all(|w| w[0] <= w[1]), "must ascend");
        // Count preserved: uniform + 5 per txn per client + makespan.
        assert_eq!(instants.len() as u64, 30 + 5 * 6 * 2 + 1);
    }

    #[test]
    fn multi_client_sharded_runs_survive_crashes() {
        for (cfg, mode, primary) in [
            (
                ServerConfig::new(PDomain::Dmp, false, RqwrbLoc::Dram),
                AppendMode::Compound,
                Primary::Write,
            ),
            (
                ServerConfig::new(PDomain::Wsp, true, RqwrbLoc::Dram),
                AppendMode::Singleton,
                Primary::Write,
            ),
            (
                ServerConfig::new(PDomain::Mhp, false, RqwrbLoc::Pm),
                AppendMode::Singleton,
                Primary::Send,
            ),
        ] {
            let opts = ShardedRunOpts {
                clients: 3,
                shards: 2,
                window: 4,
                batch: 2,
                appends_per_client: 12,
                capacity: 64,
                seed: 9,
                record: true,
            };
            let (run, _) = run_multi_client(
                cfg,
                TimingModel::default(),
                mode,
                MethodChoice::Planned(primary),
                &opts,
            );
            let rep = sharded_crash_sweep(&run, 40, 11, &RustScanner);
            assert!(
                rep.clean(),
                "{} {} sharded: {rep:?}",
                cfg.label(),
                mode.name()
            );
        }
    }
}
