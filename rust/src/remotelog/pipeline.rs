//! Windowed (pipelined) REMOTELOG client: keep up to `window` appends in
//! flight instead of waiting for each persistence point before issuing
//! the next — the throughput dimension the paper's latency-only
//! evaluation leaves open (§5 mentions pipelining as exactly what the
//! non-posted WRITE enables).
//!
//! Pipelining changes nothing about correctness obligations: an append
//! is "acked" only when *its own* persistence point is observed, and the
//! crash-consistency harness applies unchanged (the campaign in
//! `rust/tests/crash_consistency.rs` covers pipelined runs too).

use crate::fabric::sharded::ShardedFabric;
use crate::fabric::timing::{Nanos, TimingModel};
use crate::persist::config::ServerConfig;
use crate::persist::exec::{
    exec_compound, post_compound, post_compound_batch, post_singleton,
    post_singleton_batch, Update, WaitPoint,
};
use crate::persist::method::{CompoundMethod, Primary, SingletonMethod};
use crate::persist::planner::{plan_compound, plan_singleton};
use crate::remotelog::client::{
    AppendMode, AppendRecord, MethodChoice, RemoteLog,
};
use crate::remotelog::crashtest::{check_log_crash_at, CrashReport};
use crate::remotelog::log::{
    make_record, LogLayout, APP_WORDS, RECORD_BYTES,
};
use crate::remotelog::recovery::Scanner;
use crate::server::memory::Layout;
use crate::util::rng::SplitMix64;
use crate::util::stats::Histogram;
use std::collections::VecDeque;

/// Result of a pipelined run.
#[derive(Debug, Clone)]
pub struct PipelineResult {
    pub appends: u64,
    pub window: usize,
    /// Virtual time from first post to last persistence point.
    pub span_ns: Nanos,
    pub mean_latency_ns: f64,
    pub p99_latency_ns: u64,
}

impl PipelineResult {
    /// Sustained append throughput in million ops per *simulated* second.
    pub fn throughput_mops(&self) -> f64 {
        self.appends as f64 / self.span_ns as f64 * 1e3
    }
}

/// Is a compound method a pure post-train (no internal completion
/// waits), i.e. windowable and doorbell-batchable?
pub fn compound_pipelinable(m: CompoundMethod) -> bool {
    !matches!(
        m,
        CompoundMethod::WriteMsgFlushAckTwice
            | CompoundMethod::WriteImmFlushAckTwice
            | CompoundMethod::WriteFlushWaitWriteFlush
            | CompoundMethod::WriteImmFlushWaitImmFlush
    )
}

/// Is the client's configured method a pure post-train (pipelinable)?
pub fn pipelinable(rl: &RemoteLog) -> bool {
    match rl.mode {
        AppendMode::Singleton => true, // all ten singleton methods are
        AppendMode::Compound => compound_pipelinable(rl.compound_method()),
    }
}

/// Deterministic per-seq payload used by the pipelined/batched/sharded
/// runners: content depends only on `seq`, so differently scheduled runs
/// (any window, batch, or shard count) produce byte-identical logs.
pub fn pipeline_payload(seq: u64) -> [u32; APP_WORDS] {
    let mut app = [0u32; APP_WORDS];
    for (k, w) in app.iter_mut().enumerate() {
        *w = (seq as u32).wrapping_mul(0x9E37_79B9) ^ k as u32;
    }
    app
}

/// Run `n` appends keeping up to `window` in flight. Falls back to
/// sequential execution (window = 1 semantics) for methods with internal
/// waits. Latencies are recorded into `rl.latencies` as usual.
pub fn run_pipelined(rl: &mut RemoteLog, n: u64, window: usize) -> PipelineResult {
    assert!(window >= 1);
    if !pipelinable(rl) || window == 1 {
        let t0 = rl.fab.now();
        rl.run(n);
        return PipelineResult {
            appends: n,
            window: 1,
            span_ns: rl.fab.now() - t0,
            mean_latency_ns: rl.latencies.summary().mean(),
            p99_latency_ns: rl.latencies.quantile(0.99),
        };
    }

    let t0 = rl.fab.now();
    let mut inflight: VecDeque<(u64, Nanos, WaitPoint, [u8; 64])> =
        VecDeque::with_capacity(window);
    let mut payload_seq = rl.appended();

    for _ in 0..n {
        // Window full: retire the oldest append first.
        if inflight.len() == window {
            retire(rl, &mut inflight);
        }
        let seq = payload_seq;
        payload_seq += 1;
        let record = make_record(seq, &pipeline_payload(seq));
        let slot = rl.log.slot_addr(seq);
        assert!(
            seq < rl.log.capacity || !rl.fab.mem.recording(),
            "log wraparound would invalidate the crash oracle"
        );
        let start = rl.fab.now();
        let singleton_method = rl.singleton_method();
        let compound_method = rl.compound_method();
        let wp = match rl.mode {
            AppendMode::Singleton => {
                let u = Update::new(slot, record.to_vec());
                post_singleton(&mut rl.fab, singleton_method, &u, seq as u32)
            }
            AppendMode::Compound => {
                let a = Update::new(slot, record.to_vec());
                let b = Update::new(
                    rl.log.tail_addr,
                    (seq + 1).to_le_bytes().to_vec(),
                );
                post_compound(&mut rl.fab, compound_method, &a, &b, seq as u32)
                    .expect("checked pipelinable above")
            }
        };
        inflight.push_back((seq, start, wp, record));
    }
    while !inflight.is_empty() {
        retire(rl, &mut inflight);
    }
    rl.bump_seq_to(payload_seq);

    PipelineResult {
        appends: n,
        window,
        span_ns: rl.fab.now() - t0,
        mean_latency_ns: rl.latencies.summary().mean(),
        p99_latency_ns: rl.latencies.quantile(0.99),
    }
}

fn retire(
    rl: &mut RemoteLog,
    inflight: &mut VecDeque<(u64, Nanos, WaitPoint, [u8; 64])>,
) {
    let (seq, start, wp, record) = inflight.pop_front().expect("non-empty");
    let acked = wp.wait(&mut rl.fab);
    rl.latencies.record(acked - start);
    if rl.fab.mem.recording() {
        rl.appends.push(AppendRecord { seq, record, acked_at: acked });
    }
}

/// One in-flight doorbell train: `records.len()` appends sharing one
/// wait-point; every append in the train is acked when it resolves.
struct BatchTrain {
    first_seq: u64,
    start: Nanos,
    wp: WaitPoint,
    records: Vec<[u8; RECORD_BYTES]>,
}

fn retire_batch(rl: &mut RemoteLog, inflight: &mut VecDeque<BatchTrain>) {
    let train = inflight.pop_front().expect("non-empty");
    let acked = train.wp.wait(&mut rl.fab);
    for (j, rec) in train.records.iter().enumerate() {
        rl.latencies.record(acked - train.start);
        if rl.fab.mem.recording() {
            rl.appends.push(AppendRecord {
                seq: train.first_seq + j as u64,
                record: *rec,
                acked_at: acked,
            });
        }
    }
}

/// Run `n` appends as doorbell trains of `batch` records with up to
/// `window` trains in flight. Each train is one submission with ONE
/// wait-point (see [`post_singleton_batch`]); every record in a train is
/// acked at the train's persistence point. Falls back to
/// [`run_pipelined`] for `batch == 1` or methods with internal waits.
pub fn run_batched(
    rl: &mut RemoteLog,
    n: u64,
    batch: usize,
    window: usize,
) -> PipelineResult {
    assert!(batch >= 1 && window >= 1);
    if !pipelinable(rl) || batch == 1 {
        return run_pipelined(rl, n, window);
    }
    let t0 = rl.fab.now();
    let mut inflight: VecDeque<BatchTrain> = VecDeque::with_capacity(window);
    let mut seq = rl.appended();
    let end_seq = seq + n;
    assert!(
        end_seq <= rl.log.capacity || !rl.fab.mem.recording(),
        "log wraparound would invalidate the crash oracle"
    );
    let singleton_method = rl.singleton_method();
    let compound_method = rl.compound_method();

    while seq < end_seq {
        if inflight.len() == window {
            retire_batch(rl, &mut inflight);
        }
        let len = batch.min((end_seq - seq) as usize);
        let start = rl.fab.now();
        let mut records = Vec::with_capacity(len);
        let wp = match rl.mode {
            AppendMode::Singleton => {
                let mut updates = Vec::with_capacity(len);
                for j in 0..len as u64 {
                    let s = seq + j;
                    let record = make_record(s, &pipeline_payload(s));
                    updates
                        .push(Update::new(rl.log.slot_addr(s), record.to_vec()));
                    records.push(record);
                }
                post_singleton_batch(
                    &mut rl.fab,
                    singleton_method,
                    &updates,
                    seq as u32,
                )
            }
            AppendMode::Compound => {
                let mut pairs = Vec::with_capacity(len);
                for j in 0..len as u64 {
                    let s = seq + j;
                    let record = make_record(s, &pipeline_payload(s));
                    pairs.push((
                        Update::new(rl.log.slot_addr(s), record.to_vec()),
                        Update::new(
                            rl.log.tail_addr,
                            (s + 1).to_le_bytes().to_vec(),
                        ),
                    ));
                    records.push(record);
                }
                post_compound_batch(
                    &mut rl.fab,
                    compound_method,
                    &pairs,
                    seq as u32,
                )
                .expect("checked pipelinable above")
            }
        };
        inflight.push_back(BatchTrain { first_seq: seq, start, wp, records });
        seq += len as u64;
    }
    while !inflight.is_empty() {
        retire_batch(rl, &mut inflight);
    }
    rl.bump_seq_to(seq);

    PipelineResult {
        appends: n,
        window,
        span_ns: rl.fab.now() - t0,
        mean_latency_ns: rl.latencies.summary().mean(),
        p99_latency_ns: rl.latencies.quantile(0.99),
    }
}

// ---------------------------------------------------------------------
// Multi-client sharded pipelines: M clients × window-W trains over an
// N-QP fabric — the throughput-scaling axis.
// ---------------------------------------------------------------------

/// Options for a multi-client sharded run.
#[derive(Debug, Clone)]
pub struct ShardedRunOpts {
    /// Number of independent append streams.
    pub clients: usize,
    /// Number of QPs; clients are assigned round-robin (client c → QP
    /// c % shards), so `shards == clients` gives every client its own
    /// connection and `shards < clients` shares QPs (serialization).
    pub shards: usize,
    /// Doorbell trains in flight per client.
    pub window: usize,
    /// Appends per doorbell train (single wait-point per train).
    pub batch: usize,
    pub appends_per_client: u64,
    /// Log slots per client (each client gets its own PM region).
    pub capacity: u64,
    pub seed: u64,
    /// Record write timelines + oracles (required for crash sweeps).
    pub record: bool,
}

impl Default for ShardedRunOpts {
    fn default() -> Self {
        ShardedRunOpts {
            clients: 1,
            shards: 1,
            window: 8,
            batch: 1,
            appends_per_client: 1000,
            capacity: 8192,
            seed: 7,
            record: false,
        }
    }
}

/// One client of a sharded run: its QP, log region, and oracle history.
pub struct ShardedClient {
    pub qp: usize,
    pub log: LogLayout,
    /// Oracle history (populated only when recording).
    pub appends: Vec<AppendRecord>,
    pub latencies: Histogram,
}

impl ShardedClient {
    /// Number of this client's appends acked at or before `t`.
    pub fn acked_before(&self, t: Nanos) -> u64 {
        self.appends.iter().take_while(|a| a.acked_at <= t).count() as u64
    }
}

/// A completed multi-client sharded run (fabric + per-client oracles),
/// ready for crash sweeps.
pub struct ShardedRun {
    pub mode: AppendMode,
    pub fabric: ShardedFabric,
    pub clients: Vec<ShardedClient>,
    singleton_method: SingletonMethod,
    compound_method: CompoundMethod,
}

impl ShardedRun {
    pub fn singleton_method(&self) -> SingletonMethod {
        self.singleton_method
    }

    pub fn compound_method(&self) -> CompoundMethod {
        self.compound_method
    }

    fn needs_replay(&self) -> bool {
        match self.mode {
            AppendMode::Singleton => self.singleton_method.requires_replay(),
            AppendMode::Compound => self.compound_method.requires_replay(),
        }
    }
}

/// Aggregate result of a multi-client sharded run.
#[derive(Debug, Clone)]
pub struct MultiClientResult {
    pub clients: usize,
    pub shards: usize,
    pub window: usize,
    pub batch: usize,
    /// Total appends across all clients.
    pub appends: u64,
    /// Makespan: parallel virtual time from start to the last
    /// persistence point on any QP.
    pub span_ns: Nanos,
    pub mean_latency_ns: f64,
    pub p99_latency_ns: u64,
}

impl MultiClientResult {
    /// Aggregate throughput in million appends per simulated second.
    pub fn throughput_mops(&self) -> f64 {
        self.appends as f64 / self.span_ns as f64 * 1e3
    }
}

fn retire_client(
    fabric: &mut ShardedFabric,
    client: &mut ShardedClient,
    inflight: &mut VecDeque<BatchTrain>,
    summary: &mut Histogram,
    record: bool,
) {
    let train = inflight.pop_front().expect("non-empty");
    let acked = train.wp.wait(fabric.qp_mut(client.qp));
    for (j, rec) in train.records.iter().enumerate() {
        let lat = acked - train.start;
        client.latencies.record(lat);
        summary.record(lat);
        if record {
            client.appends.push(AppendRecord {
                seq: train.first_seq + j as u64,
                record: *rec,
                acked_at: acked,
            });
        }
    }
}

/// Drive `clients` append streams, each a window-W pipeline of
/// doorbell-batched trains, over an N-QP sharded fabric.
///
/// Clients co-located on one QP interleave their posts deterministically
/// (round-robin) and serialize on the shared connection; clients on
/// different QPs advance in parallel virtual time. Non-pipelinable
/// compound methods degrade to sequential execution (window = batch =
/// 1), exactly like [`run_pipelined`].
pub fn run_multi_client(
    cfg: ServerConfig,
    timing: TimingModel,
    mode: AppendMode,
    choice: MethodChoice,
    opts: &ShardedRunOpts,
) -> (ShardedRun, MultiClientResult) {
    assert!(opts.clients >= 1 && opts.shards >= 1);
    assert!(opts.window >= 1 && opts.batch >= 1);
    let (sm, cm) = match choice {
        MethodChoice::Planned(p) => {
            (plan_singleton(&cfg, p), plan_compound(&cfg, p, 8))
        }
        MethodChoice::ForcedSingleton(m) => {
            (m, plan_compound(&cfg, Primary::Write, 8))
        }
        MethodChoice::ForcedCompound(m) => {
            (plan_singleton(&cfg, Primary::Write), m)
        }
    };
    let pipelinable = match mode {
        AppendMode::Singleton => true,
        AppendMode::Compound => compound_pipelinable(cm),
    };
    let (window, batch) =
        if pipelinable { (opts.window, opts.batch) } else { (1, 1) };
    let total = opts.appends_per_client;
    assert!(
        !opts.record || total <= opts.capacity,
        "log wraparound would invalidate the crash oracle"
    );

    // Size each QP's PM for its co-located clients' log regions plus the
    // RQWRB ring (slots wide enough for batched wire envelopes).
    let clients_per_qp = opts.clients.div_ceil(opts.shards);
    let region = LogLayout::region_stride(opts.capacity);
    let rq_count = 64usize;
    let rq_slot = 8192u64;
    let pm_size = (region * clients_per_qp as u64
        + rq_count as u64 * rq_slot
        + 4096)
        .next_power_of_two();
    let layout = Layout::new(pm_size, pm_size / 2, rq_count, rq_slot, cfg.rqwrb);
    let mut fabric = ShardedFabric::new(
        cfg,
        timing,
        layout,
        opts.seed,
        opts.record,
        opts.shards,
    );

    let mut clients: Vec<ShardedClient> = (0..opts.clients)
        .map(|c| {
            let qp = c % opts.shards;
            let k = (c / opts.shards) as u64;
            let log = LogLayout::in_region(k * region, opts.capacity);
            assert!(
                log.end() <= fabric.qp(qp).mem.layout.pm_app_limit(),
                "client region overlaps the RQWRB ring"
            );
            ShardedClient {
                qp,
                log,
                appends: Vec::new(),
                latencies: Histogram::new(),
            }
        })
        .collect();

    let mut inflight: Vec<VecDeque<BatchTrain>> =
        (0..opts.clients).map(|_| VecDeque::new()).collect();
    let mut next_seq = vec![0u64; opts.clients];
    let mut summary = Histogram::new();

    // Round-robin issue loop: one train per client per pass.
    loop {
        let mut progressed = false;
        for c in 0..opts.clients {
            if next_seq[c] >= total {
                continue;
            }
            progressed = true;
            if inflight[c].len() == window {
                retire_client(
                    &mut fabric,
                    &mut clients[c],
                    &mut inflight[c],
                    &mut summary,
                    opts.record,
                );
            }
            let first = next_seq[c];
            let len = (batch as u64).min(total - first) as usize;
            let (qp, log) = (clients[c].qp, clients[c].log.clone());

            if mode == AppendMode::Compound && !pipelinable {
                // Internal-wait method: synchronous single append.
                let record = make_record(first, &pipeline_payload(first));
                let a = Update::new(log.slot_addr(first), record.to_vec());
                let b = Update::new(
                    log.tail_addr,
                    (first + 1).to_le_bytes().to_vec(),
                );
                let fab = fabric.qp_mut(qp);
                let out = exec_compound(fab, cm, &a, &b, first as u32);
                let lat = out.acked - out.start;
                clients[c].latencies.record(lat);
                summary.record(lat);
                if opts.record {
                    clients[c].appends.push(AppendRecord {
                        seq: first,
                        record,
                        acked_at: out.acked,
                    });
                }
                next_seq[c] += 1;
                continue;
            }

            let fab = fabric.qp_mut(qp);
            let start = fab.now();
            let mut records = Vec::with_capacity(len);
            let wp = match mode {
                AppendMode::Singleton => {
                    let mut updates = Vec::with_capacity(len);
                    for j in 0..len as u64 {
                        let s = first + j;
                        let record = make_record(s, &pipeline_payload(s));
                        updates.push(Update::new(
                            log.slot_addr(s),
                            record.to_vec(),
                        ));
                        records.push(record);
                    }
                    post_singleton_batch(fab, sm, &updates, first as u32)
                }
                AppendMode::Compound => {
                    let mut pairs = Vec::with_capacity(len);
                    for j in 0..len as u64 {
                        let s = first + j;
                        let record = make_record(s, &pipeline_payload(s));
                        pairs.push((
                            Update::new(log.slot_addr(s), record.to_vec()),
                            Update::new(
                                log.tail_addr,
                                (s + 1).to_le_bytes().to_vec(),
                            ),
                        ));
                        records.push(record);
                    }
                    post_compound_batch(fab, cm, &pairs, first as u32)
                        .expect("checked pipelinable above")
                }
            };
            inflight[c].push_back(BatchTrain {
                first_seq: first,
                start,
                wp,
                records,
            });
            next_seq[c] += len as u64;
        }
        if !progressed {
            break;
        }
    }
    for c in 0..opts.clients {
        while !inflight[c].is_empty() {
            retire_client(
                &mut fabric,
                &mut clients[c],
                &mut inflight[c],
                &mut summary,
                opts.record,
            );
        }
    }

    let span_ns = fabric.makespan();
    let result = MultiClientResult {
        clients: opts.clients,
        shards: opts.shards,
        window,
        batch,
        appends: total * opts.clients as u64,
        span_ns,
        mean_latency_ns: summary.summary().mean(),
        p99_latency_ns: summary.quantile(0.99),
    };
    let run = ShardedRun {
        mode,
        fabric,
        clients,
        singleton_method: sm,
        compound_method: cm,
    };
    (run, result)
}

/// Check one crash instant of a multi-client sharded run: every client's
/// log must uphold the durability/integrity/ordering contracts on its
/// own QP's crash image.
pub fn check_sharded_crash_at(
    run: &ShardedRun,
    t: Nanos,
    scanner: &dyn Scanner,
) -> CrashReport {
    let mut rep = CrashReport::default();
    for client in &run.clients {
        let fab = run.fabric.qp(client.qp);
        let image = fab.mem.crash_image(t, fab.cfg.pdomain);
        rep.merge(&check_log_crash_at(
            &image,
            &fab.mem.layout,
            &client.log,
            run.mode,
            run.needs_replay(),
            &client.appends,
            t,
            scanner,
        ));
    }
    rep.crash_points = 1;
    rep
}

/// Crash sweep over a completed sharded run: uniform global instants
/// plus the adversarial instants around every client's every ack.
pub fn sharded_crash_sweep(
    run: &ShardedRun,
    uniform_points: u64,
    seed: u64,
    scanner: &dyn Scanner,
) -> CrashReport {
    assert!(
        run.fabric.qp(0).mem.recording(),
        "crash sweep requires a recording run"
    );
    let end = run.fabric.makespan();
    let mut rng = SplitMix64::new(seed);
    let mut report = CrashReport::default();
    for _ in 0..uniform_points {
        let t = rng.next_below(end.max(1));
        report.merge(&check_sharded_crash_at(run, t, scanner));
    }
    for client in &run.clients {
        for a in &client.appends {
            for t in
                [a.acked_at, a.acked_at + 1, a.acked_at.saturating_sub(1)]
            {
                report.merge(&check_sharded_crash_at(run, t, scanner));
            }
        }
    }
    report.merge(&check_sharded_crash_at(run, end, scanner));
    report
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fabric::timing::TimingModel;
    use crate::persist::config::{PDomain, RqwrbLoc, ServerConfig};
    use crate::persist::method::Primary;
    use crate::remotelog::client::MethodChoice;
    use crate::remotelog::crashtest::crash_sweep;
    use crate::remotelog::recovery::RustScanner;

    fn client(mode: AppendMode, cfg: ServerConfig, record: bool) -> RemoteLog {
        RemoteLog::new(
            cfg,
            TimingModel::default(),
            mode,
            MethodChoice::Planned(Primary::Write),
            4096,
            5,
            record,
        )
    }

    #[test]
    fn deeper_windows_increase_throughput() {
        let cfg = ServerConfig::new(PDomain::Mhp, false, RqwrbLoc::Dram);
        let mut last = 0.0;
        for window in [1usize, 2, 8, 32] {
            let mut rl = client(AppendMode::Singleton, cfg, false);
            let res = run_pipelined(&mut rl, 3000, window);
            assert!(
                res.throughput_mops() > last,
                "window {window}: {} <= {last}",
                res.throughput_mops()
            );
            last = res.throughput_mops();
        }
        // Deep pipelining should beat sequential by a wide margin.
        assert!(last > 1.0, "expected >1 Mops at window 32, got {last}");
    }

    #[test]
    fn latency_grows_modestly_under_pipelining() {
        let cfg = ServerConfig::new(PDomain::Wsp, false, RqwrbLoc::Dram);
        let mut seq = client(AppendMode::Singleton, cfg, false);
        let s = run_pipelined(&mut seq, 2000, 1);
        let mut pipe = client(AppendMode::Singleton, cfg, false);
        let p = run_pipelined(&mut pipe, 2000, 16);
        assert!(p.throughput_mops() > 4.0 * s.throughput_mops());
        // Per-append latency may rise (queueing) but not explode.
        assert!(p.mean_latency_ns < 20.0 * s.mean_latency_ns);
    }

    #[test]
    fn pipelined_compound_methods_detected() {
        let dmp_ddio = ServerConfig::new(PDomain::Dmp, true, RqwrbLoc::Dram);
        let rl = client(AppendMode::Compound, dmp_ddio, false);
        // 2x message round trips — not pipelinable.
        assert!(!pipelinable(&rl));
        let mhp = ServerConfig::new(PDomain::Mhp, false, RqwrbLoc::Dram);
        let rl = client(AppendMode::Compound, mhp, false);
        assert!(pipelinable(&rl));
    }

    #[test]
    fn pipelined_runs_survive_crashes() {
        for cfg in [
            ServerConfig::new(PDomain::Dmp, false, RqwrbLoc::Dram),
            ServerConfig::new(PDomain::Mhp, true, RqwrbLoc::Dram),
            ServerConfig::new(PDomain::Wsp, false, RqwrbLoc::Pm),
        ] {
            for mode in [AppendMode::Singleton, AppendMode::Compound] {
                let mut rl = RemoteLog::new(
                    cfg,
                    TimingModel::default(),
                    mode,
                    MethodChoice::Planned(Primary::Write),
                    64,
                    9,
                    true,
                );
                run_pipelined(&mut rl, 40, 8);
                let rep = crash_sweep(&rl, 80, 3, &RustScanner);
                assert!(
                    rep.clean(),
                    "{} {} pipelined: {rep:?}",
                    cfg.label(),
                    mode.name()
                );
            }
        }
    }

    #[test]
    fn sequence_continues_after_pipelined_run() {
        let cfg = ServerConfig::new(PDomain::Wsp, false, RqwrbLoc::Dram);
        let mut rl = client(AppendMode::Singleton, cfg, false);
        run_pipelined(&mut rl, 100, 8);
        assert_eq!(rl.appended(), 100);
        rl.append();
        assert_eq!(rl.appended(), 101);
    }

    #[test]
    fn batched_trains_beat_unbatched_pipelining() {
        let cfg = ServerConfig::new(PDomain::Mhp, false, RqwrbLoc::Dram);
        let mut plain = client(AppendMode::Singleton, cfg, false);
        let p = run_pipelined(&mut plain, 3000, 8);
        let mut batched = client(AppendMode::Singleton, cfg, false);
        let b = run_batched(&mut batched, 3000, 8, 8);
        assert!(
            b.throughput_mops() > p.throughput_mops(),
            "batched {} <= pipelined {}",
            b.throughput_mops(),
            p.throughput_mops()
        );
        assert_eq!(batched.appended(), 3000);
    }

    #[test]
    fn multi_client_scaling_is_monotone() {
        let cfg = ServerConfig::new(PDomain::Mhp, false, RqwrbLoc::Dram);
        let mut last = 0.0;
        for m in [1usize, 2, 4, 8] {
            let opts = ShardedRunOpts {
                clients: m,
                shards: m,
                window: 8,
                batch: 4,
                appends_per_client: 400,
                capacity: 512,
                seed: 3,
                record: false,
            };
            let (_, res) = run_multi_client(
                cfg,
                TimingModel::default(),
                AppendMode::Singleton,
                MethodChoice::Planned(Primary::Write),
                &opts,
            );
            assert!(
                res.throughput_mops() >= last,
                "clients {m}: {} < {last}",
                res.throughput_mops()
            );
            last = res.throughput_mops();
        }
    }

    #[test]
    fn sharding_relieves_responder_cpu_bottleneck() {
        // Two-sided methods serialize on the responder CPU, so 4 clients
        // crammed onto 1 QP (one responder) are CPU-bound; spread over 4
        // QPs they get 4 responder CPUs and overlap.
        let cfg = ServerConfig::new(PDomain::Dmp, true, RqwrbLoc::Dram);
        let mut spans = Vec::new();
        for shards in [1usize, 4] {
            let opts = ShardedRunOpts {
                clients: 4,
                shards,
                window: 4,
                batch: 2,
                appends_per_client: 300,
                capacity: 512,
                seed: 5,
                record: false,
            };
            let (run, res) = run_multi_client(
                cfg,
                TimingModel::default(),
                AppendMode::Singleton,
                MethodChoice::Planned(Primary::Send),
                &opts,
            );
            assert_eq!(
                run.singleton_method(),
                crate::persist::method::SingletonMethod::SendCopyFlushAck
            );
            spans.push(res.span_ns);
        }
        assert!(
            spans[1] * 2 < spans[0],
            "4 QPs ({}) should be >2x faster than 1 QP ({})",
            spans[1],
            spans[0]
        );
    }

    #[test]
    fn multi_client_sharded_runs_survive_crashes() {
        for (cfg, mode, primary) in [
            (
                ServerConfig::new(PDomain::Dmp, false, RqwrbLoc::Dram),
                AppendMode::Compound,
                Primary::Write,
            ),
            (
                ServerConfig::new(PDomain::Wsp, true, RqwrbLoc::Dram),
                AppendMode::Singleton,
                Primary::Write,
            ),
            (
                ServerConfig::new(PDomain::Mhp, false, RqwrbLoc::Pm),
                AppendMode::Singleton,
                Primary::Send,
            ),
        ] {
            let opts = ShardedRunOpts {
                clients: 3,
                shards: 2,
                window: 4,
                batch: 2,
                appends_per_client: 12,
                capacity: 64,
                seed: 9,
                record: true,
            };
            let (run, _) = run_multi_client(
                cfg,
                TimingModel::default(),
                mode,
                MethodChoice::Planned(primary),
                &opts,
            );
            let rep = sharded_crash_sweep(&run, 40, 11, &RustScanner);
            assert!(
                rep.clean(),
                "{} {} sharded: {rep:?}",
                cfg.label(),
                mode.name()
            );
        }
    }
}
