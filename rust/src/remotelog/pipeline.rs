//! Windowed (pipelined) REMOTELOG client: keep up to `window` appends in
//! flight instead of waiting for each persistence point before issuing
//! the next — the throughput dimension the paper's latency-only
//! evaluation leaves open (§5 mentions pipelining as exactly what the
//! non-posted WRITE enables).
//!
//! Pipelining changes nothing about correctness obligations: an append
//! is "acked" only when *its own* persistence point is observed, and the
//! crash-consistency harness applies unchanged (the campaign in
//! `rust/tests/crash_consistency.rs` covers pipelined runs too).

use crate::fabric::timing::Nanos;
use crate::persist::exec::{post_compound, post_singleton, Update, WaitPoint};
use crate::remotelog::client::{AppendMode, AppendRecord, RemoteLog};
use crate::remotelog::log::{make_record, APP_WORDS};
use std::collections::VecDeque;

/// Result of a pipelined run.
#[derive(Debug, Clone)]
pub struct PipelineResult {
    pub appends: u64,
    pub window: usize,
    /// Virtual time from first post to last persistence point.
    pub span_ns: Nanos,
    pub mean_latency_ns: f64,
    pub p99_latency_ns: u64,
}

impl PipelineResult {
    /// Sustained append throughput in million ops per *simulated* second.
    pub fn throughput_mops(&self) -> f64 {
        self.appends as f64 / self.span_ns as f64 * 1e3
    }
}

/// Is the client's configured method a pure post-train (pipelinable)?
pub fn pipelinable(rl: &RemoteLog) -> bool {
    match rl.mode {
        AppendMode::Singleton => true, // all ten singleton methods are
        AppendMode::Compound => !matches!(
            rl.compound_method(),
            crate::persist::method::CompoundMethod::WriteMsgFlushAckTwice
                | crate::persist::method::CompoundMethod::WriteImmFlushAckTwice
                | crate::persist::method::CompoundMethod::WriteFlushWaitWriteFlush
                | crate::persist::method::CompoundMethod::WriteImmFlushWaitImmFlush
        ),
    }
}

/// Run `n` appends keeping up to `window` in flight. Falls back to
/// sequential execution (window = 1 semantics) for methods with internal
/// waits. Latencies are recorded into `rl.latencies` as usual.
pub fn run_pipelined(rl: &mut RemoteLog, n: u64, window: usize) -> PipelineResult {
    assert!(window >= 1);
    if !pipelinable(rl) || window == 1 {
        let t0 = rl.fab.now();
        rl.run(n);
        return PipelineResult {
            appends: n,
            window: 1,
            span_ns: rl.fab.now() - t0,
            mean_latency_ns: rl.latencies.summary().mean(),
            p99_latency_ns: rl.latencies.quantile(0.99),
        };
    }

    let t0 = rl.fab.now();
    let mut inflight: VecDeque<(u64, Nanos, WaitPoint, [u8; 64])> =
        VecDeque::with_capacity(window);
    let mut payload_seq = rl.appended();

    for _ in 0..n {
        // Window full: retire the oldest append first.
        if inflight.len() == window {
            retire(rl, &mut inflight);
        }
        let seq = payload_seq;
        payload_seq += 1;
        let mut app = [0u32; APP_WORDS];
        for (k, w) in app.iter_mut().enumerate() {
            *w = (seq as u32).wrapping_mul(0x9E37_79B9) ^ k as u32;
        }
        let record = make_record(seq, &app);
        let slot = rl.log.slot_addr(seq);
        assert!(
            seq < rl.log.capacity || !rl.fab.mem.recording(),
            "log wraparound would invalidate the crash oracle"
        );
        let start = rl.fab.now();
        let singleton_method = rl.singleton_method();
        let compound_method = rl.compound_method();
        let wp = match rl.mode {
            AppendMode::Singleton => {
                let u = Update::new(slot, record.to_vec());
                post_singleton(&mut rl.fab, singleton_method, &u, seq as u32)
            }
            AppendMode::Compound => {
                let a = Update::new(slot, record.to_vec());
                let b = Update::new(
                    rl.log.tail_addr,
                    (seq + 1).to_le_bytes().to_vec(),
                );
                post_compound(&mut rl.fab, compound_method, &a, &b, seq as u32)
                    .expect("checked pipelinable above")
            }
        };
        inflight.push_back((seq, start, wp, record));
    }
    while !inflight.is_empty() {
        retire(rl, &mut inflight);
    }
    rl.bump_seq_to(payload_seq);

    PipelineResult {
        appends: n,
        window,
        span_ns: rl.fab.now() - t0,
        mean_latency_ns: rl.latencies.summary().mean(),
        p99_latency_ns: rl.latencies.quantile(0.99),
    }
}

fn retire(
    rl: &mut RemoteLog,
    inflight: &mut VecDeque<(u64, Nanos, WaitPoint, [u8; 64])>,
) {
    let (seq, start, wp, record) = inflight.pop_front().expect("non-empty");
    let acked = wp.wait(&mut rl.fab);
    rl.latencies.record(acked - start);
    if rl.fab.mem.recording() {
        rl.appends.push(AppendRecord { seq, record, acked_at: acked });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fabric::timing::TimingModel;
    use crate::persist::config::{PDomain, RqwrbLoc, ServerConfig};
    use crate::persist::method::Primary;
    use crate::remotelog::client::MethodChoice;
    use crate::remotelog::crashtest::crash_sweep;
    use crate::remotelog::recovery::RustScanner;

    fn client(mode: AppendMode, cfg: ServerConfig, record: bool) -> RemoteLog {
        RemoteLog::new(
            cfg,
            TimingModel::default(),
            mode,
            MethodChoice::Planned(Primary::Write),
            4096,
            5,
            record,
        )
    }

    #[test]
    fn deeper_windows_increase_throughput() {
        let cfg = ServerConfig::new(PDomain::Mhp, false, RqwrbLoc::Dram);
        let mut last = 0.0;
        for window in [1usize, 2, 8, 32] {
            let mut rl = client(AppendMode::Singleton, cfg, false);
            let res = run_pipelined(&mut rl, 3000, window);
            assert!(
                res.throughput_mops() > last,
                "window {window}: {} <= {last}",
                res.throughput_mops()
            );
            last = res.throughput_mops();
        }
        // Deep pipelining should beat sequential by a wide margin.
        assert!(last > 1.0, "expected >1 Mops at window 32, got {last}");
    }

    #[test]
    fn latency_grows_modestly_under_pipelining() {
        let cfg = ServerConfig::new(PDomain::Wsp, false, RqwrbLoc::Dram);
        let mut seq = client(AppendMode::Singleton, cfg, false);
        let s = run_pipelined(&mut seq, 2000, 1);
        let mut pipe = client(AppendMode::Singleton, cfg, false);
        let p = run_pipelined(&mut pipe, 2000, 16);
        assert!(p.throughput_mops() > 4.0 * s.throughput_mops());
        // Per-append latency may rise (queueing) but not explode.
        assert!(p.mean_latency_ns < 20.0 * s.mean_latency_ns);
    }

    #[test]
    fn pipelined_compound_methods_detected() {
        let dmp_ddio = ServerConfig::new(PDomain::Dmp, true, RqwrbLoc::Dram);
        let rl = client(AppendMode::Compound, dmp_ddio, false);
        // 2x message round trips — not pipelinable.
        assert!(!pipelinable(&rl));
        let mhp = ServerConfig::new(PDomain::Mhp, false, RqwrbLoc::Dram);
        let rl = client(AppendMode::Compound, mhp, false);
        assert!(pipelinable(&rl));
    }

    #[test]
    fn pipelined_runs_survive_crashes() {
        for cfg in [
            ServerConfig::new(PDomain::Dmp, false, RqwrbLoc::Dram),
            ServerConfig::new(PDomain::Mhp, true, RqwrbLoc::Dram),
            ServerConfig::new(PDomain::Wsp, false, RqwrbLoc::Pm),
        ] {
            for mode in [AppendMode::Singleton, AppendMode::Compound] {
                let mut rl = RemoteLog::new(
                    cfg,
                    TimingModel::default(),
                    mode,
                    MethodChoice::Planned(Primary::Write),
                    64,
                    9,
                    true,
                );
                run_pipelined(&mut rl, 40, 8);
                let rep = crash_sweep(&rl, 80, 3, &RustScanner);
                assert!(
                    rep.clean(),
                    "{} {} pipelined: {rep:?}",
                    cfg.label(),
                    mode.name()
                );
            }
        }
    }

    #[test]
    fn sequence_continues_after_pipelined_run() {
        let cfg = ServerConfig::new(PDomain::Wsp, false, RqwrbLoc::Dram);
        let mut rl = client(AppendMode::Singleton, cfg, false);
        run_pipelined(&mut rl, 100, 8);
        assert_eq!(rl.appended(), 100);
        rl.append();
        assert_eq!(rl.appended(), 101);
    }
}
