//! REMOTELOG — the paper's evaluation workload (§4): log replication over
//! RDMA with checksummed records (singleton updates) or an explicitly
//! managed tail pointer (compound updates), plus the crash-recovery
//! subsystem and the crash-consistency harness that *proves* each
//! persistence method correct (or demonstrably incorrect).

pub mod antientropy;
pub mod client;
pub mod crashtest;
pub mod log;
pub mod pipeline;
pub mod recovery;

pub use client::{AppendMode, AppendRecord, MethodChoice, RemoteLog};
pub use crashtest::{check_crash_at, crash_sweep, CrashReport};
pub use log::{LogLayout, APP_WORDS, PAYLOAD_WORDS, RECORD_BYTES, RECORD_WORDS};
pub use recovery::{recover, RecoveryResult, RustScanner, Scanner};
