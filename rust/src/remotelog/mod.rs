//! REMOTELOG — the paper's evaluation workload (§4): log replication over
//! RDMA with checksummed records (singleton updates) or an explicitly
//! managed tail pointer (compound updates), plus the crash-recovery
//! subsystem and the crash-consistency harness that *proves* each
//! persistence method correct (or demonstrably incorrect), plus the
//! hostile-network soak campaign ([`soak`]) that re-proves the 2PC
//! invariants under drop/jitter/partition/churn fault schedules.

pub mod antientropy;
pub mod client;
pub mod crashtest;
pub mod log;
pub mod pipeline;
pub mod recovery;
pub mod soak;

pub use client::{AppendMode, AppendRecord, MethodChoice, RemoteLog};
pub use crashtest::{check_crash_at, crash_sweep, CrashReport};
pub use log::{LogLayout, APP_WORDS, PAYLOAD_WORDS, RECORD_BYTES, RECORD_WORDS};
pub use recovery::{recover, RecoveryResult, RustScanner, Scanner};
pub use soak::{
    replay_line, run_soak_case, run_txn_soak, shrink_soak_failure,
    soak_check, FaultPlan, SoakOpts, SoakReport, SoakStats,
};
