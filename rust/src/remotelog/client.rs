//! REMOTELOG client: replicates a log to the remote responder using the
//! planner-selected (or explicitly forced) persistence method.
//!
//! Two append modes, matching the paper's two REMOTELOG variants (§4.1):
//!
//! * **Singleton** — checksummed records only; the responder finds the
//!   tail by checksum failure. One singleton RDMA update per append.
//! * **Compound** — records plus an explicitly managed tail pointer:
//!   append record `a`, then persist the 8-byte tail pointer `b`,
//!   strictly in that order.

use crate::fabric::engine::Fabric;
use crate::fabric::timing::{Nanos, TimingModel};
use crate::persist::config::ServerConfig;
use crate::persist::exec::{exec_compound, exec_singleton, PersistOutcome, Update};
use crate::persist::method::{CompoundMethod, Primary, SingletonMethod};
use crate::persist::planner::{plan_compound, plan_singleton};
use crate::remotelog::log::{make_record, LogLayout, APP_WORDS, RECORD_BYTES};
use crate::server::memory::Layout;
use crate::util::rng::SplitMix64;
use crate::util::stats::Histogram;

/// Which REMOTELOG variant an experiment runs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AppendMode {
    /// Checksummed records only; recovery finds the tail by checksum
    /// failure.
    Singleton,
    /// Record + explicitly managed tail pointer, strictly ordered.
    Compound,
}

impl AppendMode {
    /// Short label used in tables and test output.
    pub fn name(&self) -> &'static str {
        match self {
            AppendMode::Singleton => "singleton",
            AppendMode::Compound => "compound",
        }
    }
}

/// The method actually used — planned or forced (for
/// wrong-method-demonstration and ablation experiments).
#[derive(Debug, Clone, Copy)]
pub enum MethodChoice {
    /// Let the planner pick the correct method for the configuration.
    Planned(Primary),
    /// Force a specific singleton method (wrong-method demos).
    ForcedSingleton(SingletonMethod),
    /// Force a specific compound method (wrong-method demos).
    ForcedCompound(CompoundMethod),
}

/// Oracle record of one append, kept by the client for crash checking.
#[derive(Debug, Clone)]
pub struct AppendRecord {
    /// Append sequence number (log slot).
    pub seq: u64,
    /// The exact record image appended.
    pub record: [u8; RECORD_BYTES],
    /// Requester clock when the persistence point was observed.
    pub acked_at: Nanos,
}

/// A REMOTELOG client bound to one simulated responder.
pub struct RemoteLog {
    /// The QP + responder this log replicates to.
    pub fab: Fabric,
    /// Where the log lives in responder PM.
    pub log: LogLayout,
    /// Which REMOTELOG variant this client runs.
    pub mode: AppendMode,
    singleton_method: SingletonMethod,
    compound_method: CompoundMethod,
    next_seq: u64,
    /// Oracle history (only populated when the fabric records writes).
    pub appends: Vec<AppendRecord>,
    /// Per-append latencies.
    pub latencies: Histogram,
    payload_rng: SplitMix64,
}

impl RemoteLog {
    /// Build a client + simulated responder for `cfg`.
    ///
    /// `capacity`: log slots; `record`: keep write timelines + oracle
    /// history (required for crash testing, off for pure benchmarking).
    pub fn new(
        cfg: ServerConfig,
        timing: TimingModel,
        mode: AppendMode,
        choice: MethodChoice,
        capacity: u64,
        seed: u64,
        record: bool,
    ) -> Self {
        let log = LogLayout::new(capacity);
        // PM must hold the log region plus the RQWRB ring. Slots are
        // sized for doorbell-batched wire envelopes (several records per
        // message), not just singletons.
        let rq_count = 64;
        let rq_slot = 1024u64;
        let pm_size = (log.end() + rq_count as u64 * rq_slot + 4096)
            .next_power_of_two();
        let layout = Layout::new(pm_size, pm_size / 2, rq_count, rq_slot, cfg.rqwrb);
        assert!(
            log.end() <= layout.pm_app_limit(),
            "log overlaps the RQWRB ring"
        );
        let fab = Fabric::new(cfg, timing, layout, seed, record);

        let (sm, cm) = match choice {
            MethodChoice::Planned(p) => {
                (plan_singleton(&cfg, p), plan_compound(&cfg, p, 8))
            }
            MethodChoice::ForcedSingleton(m) => {
                (m, plan_compound(&cfg, Primary::Write, 8))
            }
            MethodChoice::ForcedCompound(m) => {
                (plan_singleton(&cfg, Primary::Write), m)
            }
        };

        RemoteLog {
            fab,
            log,
            mode,
            singleton_method: sm,
            compound_method: cm,
            next_seq: 0,
            appends: Vec::new(),
            latencies: Histogram::new(),
            payload_rng: SplitMix64::new(seed ^ 0xA5A5_5A5A),
        }
    }

    /// The singleton method appends execute with.
    pub fn singleton_method(&self) -> SingletonMethod {
        self.singleton_method
    }

    /// The compound method appends execute with.
    pub fn compound_method(&self) -> CompoundMethod {
        self.compound_method
    }

    /// Appends issued so far (= next sequence number).
    pub fn appended(&self) -> u64 {
        self.next_seq
    }

    /// Advance the append sequence counter (used by the pipelined runner,
    /// which posts records itself).
    pub(crate) fn bump_seq_to(&mut self, seq: u64) {
        debug_assert!(seq >= self.next_seq);
        self.next_seq = seq;
    }

    /// Append one record with caller-supplied payload words.
    pub fn append_payload(&mut self, app: &[u32; APP_WORDS]) -> PersistOutcome {
        let seq = self.next_seq;
        let record = make_record(seq, app);
        let slot = self.log.slot_addr(seq);
        assert!(
            seq < self.log.capacity || !self.fab.mem.recording(),
            "log wraparound would invalidate the crash oracle"
        );

        let out = match self.mode {
            AppendMode::Singleton => {
                let u = Update::new(slot, record.to_vec());
                exec_singleton(&mut self.fab, self.singleton_method, &u, seq as u32)
            }
            AppendMode::Compound => {
                let a = Update::new(slot, record.to_vec());
                // Tail pointer value = number of durable records = seq+1.
                let b = Update::new(
                    self.log.tail_addr,
                    (seq + 1).to_le_bytes().to_vec(),
                );
                exec_compound(&mut self.fab, self.compound_method, &a, &b, seq as u32)
            }
        };

        self.next_seq += 1;
        if self.fab.mem.recording() {
            self.appends.push(AppendRecord {
                seq,
                record,
                acked_at: out.acked,
            });
        }
        self.latencies.record(out.latency());
        out
    }

    /// Append one record with pseudorandom payload.
    pub fn append(&mut self) -> PersistOutcome {
        let mut app = [0u32; APP_WORDS];
        for w in &mut app {
            *w = self.payload_rng.next_u32();
        }
        self.append_payload(&app)
    }

    /// Run `n` appends back-to-back; returns mean latency (ns).
    pub fn run(&mut self, n: u64) -> f64 {
        for _ in 0..n {
            self.append();
        }
        self.latencies.summary().mean()
    }

    /// Number of appends acked at or before virtual time `t`.
    pub fn acked_before(&self, t: Nanos) -> u64 {
        self.appends.iter().take_while(|a| a.acked_at <= t).count() as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::persist::config::{PDomain, RqwrbLoc};

    fn client(mode: AppendMode) -> RemoteLog {
        let cfg = ServerConfig::new(PDomain::Mhp, false, RqwrbLoc::Dram);
        RemoteLog::new(
            cfg,
            TimingModel::deterministic(),
            mode,
            MethodChoice::Planned(Primary::Write),
            1024,
            1,
            true,
        )
    }

    #[test]
    fn appends_advance_sequence_and_clock() {
        let mut c = client(AppendMode::Singleton);
        let o1 = c.append();
        let o2 = c.append();
        assert_eq!(c.appended(), 2);
        assert!(o2.start >= o1.acked);
        assert_eq!(c.appends.len(), 2);
        assert!(c.appends[0].acked_at < c.appends[1].acked_at);
    }

    #[test]
    fn singleton_records_land_in_slots() {
        let mut c = client(AppendMode::Singleton);
        c.append();
        c.append();
        let img = c.fab.mem.visible_image(u64::MAX - 1);
        let rec0 = img.read(c.log.slot_addr(0), RECORD_BYTES);
        let rec1 = img.read(c.log.slot_addr(1), RECORD_BYTES);
        assert_eq!(rec0, &c.appends[0].record[..]);
        assert_eq!(rec1, &c.appends[1].record[..]);
    }

    #[test]
    fn compound_updates_tail_pointer() {
        let mut c = client(AppendMode::Compound);
        c.append();
        c.append();
        c.append();
        let img = c.fab.mem.visible_image(u64::MAX - 1);
        assert_eq!(img.read_u64(c.log.tail_addr), 3);
    }

    #[test]
    fn acked_before_counts_prefix() {
        let mut c = client(AppendMode::Singleton);
        for _ in 0..5 {
            c.append();
        }
        let t2 = c.appends[1].acked_at;
        assert_eq!(c.acked_before(t2), 2);
        assert_eq!(c.acked_before(0), 0);
        assert_eq!(c.acked_before(u64::MAX), 5);
    }

    #[test]
    fn mean_latency_positive_and_stable() {
        let mut c = client(AppendMode::Singleton);
        let mean = c.run(50);
        assert!(mean > 1000.0, "sub-microsecond append is implausible");
        assert_eq!(c.latencies.summary().count(), 50);
    }

    #[test]
    #[should_panic(expected = "wraparound")]
    fn wraparound_rejected_when_recording() {
        let cfg = ServerConfig::new(PDomain::Mhp, false, RqwrbLoc::Dram);
        let mut c = RemoteLog::new(
            cfg,
            TimingModel::deterministic(),
            AppendMode::Singleton,
            MethodChoice::Planned(Primary::Write),
            4,
            1,
            true,
        );
        for _ in 0..5 {
            c.append();
        }
    }
}
