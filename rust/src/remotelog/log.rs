//! REMOTELOG record format and log region layout (paper §4.1).
//!
//! 64-byte records = 16 little-endian u32 words:
//!
//! ```text
//! word 0        append sequence number (low 32 bits)
//! words 1..14   application payload (13 words)
//! word 14       Fletcher s1 over words 0..14
//! word 15       Fletcher s2 over words 0..14
//! ```
//!
//! The checksum serves two purposes from the paper: *tail detection* for
//! the singleton-update log ("the server detects the log tail when its
//! checksum fails") and *torn-write detection*. The geometry mirrors
//! `python/compile/kernels/ref.py` exactly; the recovery scan can run
//! through either the rust mirror or the AOT-compiled Pallas kernel.

use crate::integrity::fletcher_words;

/// Bytes per log record.
pub const RECORD_BYTES: usize = 64;
/// u32 words per log record.
pub const RECORD_WORDS: usize = 16;
/// Checksummed words (includes the seq word).
pub const PAYLOAD_WORDS: usize = 14;
/// Caller-supplied payload words.
pub const APP_WORDS: usize = 13;

/// Build a record image for append `seq` with 13 application words.
pub fn make_record(seq: u64, app: &[u32; APP_WORDS]) -> [u8; RECORD_BYTES] {
    let mut words = [0u32; RECORD_WORDS];
    words[0] = seq as u32;
    words[1..1 + APP_WORDS].copy_from_slice(app);
    let (s1, s2) = fletcher_words(&words[..PAYLOAD_WORDS]);
    words[14] = s1;
    words[15] = s2;
    let mut bytes = [0u8; RECORD_BYTES];
    for (i, w) in words.iter().enumerate() {
        bytes[i * 4..i * 4 + 4].copy_from_slice(&w.to_le_bytes());
    }
    bytes
}

/// Parse a 64-byte record image into words.
pub fn record_words(bytes: &[u8]) -> [u32; RECORD_WORDS] {
    assert_eq!(bytes.len(), RECORD_BYTES);
    let mut words = [0u32; RECORD_WORDS];
    for (i, w) in words.iter_mut().enumerate() {
        *w = u32::from_le_bytes(bytes[i * 4..i * 4 + 4].try_into().unwrap());
    }
    words
}

/// Is this record image checksum-valid?
pub fn record_valid(bytes: &[u8]) -> bool {
    let words = record_words(bytes);
    let (s1, s2) = fletcher_words(&words[..PAYLOAD_WORDS]);
    words[14] == s1 && words[15] == s2
}

/// Sequence number stored in a record image.
pub fn record_seq(bytes: &[u8]) -> u32 {
    u32::from_le_bytes(bytes[0..4].try_into().unwrap())
}

/// Placement of the log inside responder PM.
#[derive(Debug, Clone)]
pub struct LogLayout {
    /// Address of the explicit tail pointer (compound mode), 8 bytes.
    pub tail_addr: u64,
    /// First record slot address.
    pub base: u64,
    /// Number of record slots.
    pub capacity: u64,
}

impl LogLayout {
    /// Conventional placement: tail pointer at 0x40, records from 0x1000.
    pub fn new(capacity: u64) -> Self {
        LogLayout::in_region(0, capacity)
    }

    /// Place a log inside the PM region starting at `region_base`: tail
    /// pointer at base+0x40, records from base+0x1000. The sharded
    /// multi-client driver uses this to give clients co-located on one
    /// QP disjoint log regions.
    pub fn in_region(region_base: u64, capacity: u64) -> Self {
        LogLayout {
            tail_addr: region_base + 0x40,
            base: region_base + 0x1000,
            capacity,
        }
    }

    /// PM bytes a client region needs (header page + records), rounded
    /// to a page so regions tile without overlap.
    pub fn region_stride(capacity: u64) -> u64 {
        (0x1000 + capacity * RECORD_BYTES as u64).next_multiple_of(0x1000)
    }

    /// PM address of the slot for append `seq` (modular ring).
    pub fn slot_addr(&self, seq: u64) -> u64 {
        self.base + (seq % self.capacity) * RECORD_BYTES as u64
    }

    /// Bytes of PM the log region occupies (tail pointer region included).
    pub fn end(&self) -> u64 {
        self.base + self.capacity * RECORD_BYTES as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_roundtrip_valid() {
        let rec = make_record(7, &[3u32; APP_WORDS]);
        assert!(record_valid(&rec));
        assert_eq!(record_seq(&rec), 7);
        let words = record_words(&rec);
        assert_eq!(words[0], 7);
        assert_eq!(words[1], 3);
    }

    #[test]
    fn corruption_detected_everywhere() {
        let rec = make_record(1, &[0xABCD_EF01; APP_WORDS]);
        for byte in 0..RECORD_BYTES {
            let mut bad = rec;
            bad[byte] ^= 0x40;
            assert!(!record_valid(&bad), "flip at byte {byte} undetected");
        }
    }

    #[test]
    fn zeroed_slot_invalid() {
        assert!(!record_valid(&[0u8; RECORD_BYTES]));
    }

    #[test]
    fn seq_wraps_at_u32() {
        let rec = make_record(u32::MAX as u64 + 5, &[0; APP_WORDS]);
        assert_eq!(record_seq(&rec), 4);
        assert!(record_valid(&rec));
    }

    #[test]
    fn layout_slot_addresses_wrap() {
        let l = LogLayout::new(8);
        assert_eq!(l.slot_addr(0), l.base);
        assert_eq!(l.slot_addr(8), l.base);
        assert_eq!(l.slot_addr(3), l.base + 3 * 64);
        assert!(l.end() > l.base);
    }

    #[test]
    fn regions_tile_without_overlap() {
        let stride = LogLayout::region_stride(32);
        let a = LogLayout::in_region(0, 32);
        let b = LogLayout::in_region(stride, 32);
        assert_eq!(a.tail_addr, LogLayout::new(32).tail_addr);
        assert!(a.end() <= b.tail_addr, "regions must not overlap");
        assert!(b.tail_addr < b.base);
        assert_eq!(b.slot_addr(0), b.base);
        // 0x1000 header + 32*64 B of records, rounded to a page.
        assert_eq!(stride, 0x2000);
    }

    #[test]
    fn matches_python_oracle_vector() {
        // Cross-language pin: zero payload, seq 0 -> s1 = 1, s2 = 14
        // (see ref.py: zero record has s1=1, s2=PAYLOAD_WORDS).
        let rec = make_record(0, &[0; APP_WORDS]);
        let words = record_words(&rec);
        assert_eq!(words[14], 1);
        assert_eq!(words[15], 14);
    }
}
