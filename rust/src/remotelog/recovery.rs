//! Post-crash recovery for REMOTELOG (paper §3.2/§3.3 recovery-subsystem
//! discussion, §4.1 tail detection).
//!
//! Recovery operates on a reconstructed crash [`Image`]:
//!
//! 1. **RQWRB replay** — for methods that persist the *message* rather
//!    than the target (one-sided SEND with PM-resident RQWRBs), parse the
//!    surviving receive-buffer ring, integrity-check each message, and
//!    apply valid messages to their target addresses in message-sequence
//!    order.
//! 2. **Tail detection** — singleton mode: scan records from the log base
//!    and stop at the first checksum-invalid record. Compound mode: read
//!    the explicit tail pointer, then verify the checksum + sequence
//!    chain of the records it covers (a torn/unordered suffix clamps the
//!    recovered tail).
//!
//! The scan can run through the rust mirror ([`RustScanner`]) or through
//! the AOT-compiled Pallas kernel via PJRT ([`crate::runtime::XlaScanner`])
//! — both implement [`Scanner`] and must agree bit-for-bit.

use crate::persist::wire;
use crate::remotelog::client::AppendMode;
use crate::remotelog::log::{
    record_seq, record_valid, LogLayout, RECORD_BYTES,
};
use crate::server::memory::{Image, Layout};

/// Tail-detection backend.
pub trait Scanner {
    /// For `records` = concatenated 64-byte record images, return
    /// (validity mask, first-invalid index).
    fn scan(&self, records: &[u8]) -> (Vec<bool>, u64);

    /// Verify a checksum+sequence chain starting at `base_seq`; returns
    /// the length of the longest valid prefix.
    fn verify_chain(&self, records: &[u8], base_seq: u32) -> u64 {
        let (valid, _) = self.scan(records);
        let n = records.len() / RECORD_BYTES;
        for i in 0..n {
            let rec = &records[i * RECORD_BYTES..(i + 1) * RECORD_BYTES];
            if !valid[i] || record_seq(rec) != base_seq.wrapping_add(i as u32) {
                return i as u64;
            }
        }
        n as u64
    }

    /// Backend name (reports and CLI output).
    fn name(&self) -> &'static str;
}

/// Pure-rust tail detection (the hot-path mirror of the Pallas kernel).
pub struct RustScanner;

impl Scanner for RustScanner {
    fn scan(&self, records: &[u8]) -> (Vec<bool>, u64) {
        assert_eq!(records.len() % RECORD_BYTES, 0);
        let n = records.len() / RECORD_BYTES;
        let mut valid = Vec::with_capacity(n);
        let mut tail = n as u64;
        for i in 0..n {
            let ok =
                record_valid(&records[i * RECORD_BYTES..(i + 1) * RECORD_BYTES]);
            valid.push(ok);
            if !ok && (i as u64) < tail {
                tail = i as u64;
            }
        }
        (valid, tail)
    }

    fn name(&self) -> &'static str {
        "rust"
    }
}

/// Outcome of a recovery pass.
#[derive(Debug, Clone)]
pub struct RecoveryResult {
    /// Number of records recovered (the durable log prefix).
    pub recovered: u64,
    /// Messages replayed from the RQWRB ring.
    pub replayed: u32,
    /// The raw tail-pointer value read from PM (compound mode).
    pub tail_ptr: Option<u64>,
    /// Recovered record images, concatenated.
    pub records: Vec<u8>,
}

/// Run recovery over a crash image.
///
/// `replay` should be true when the workload used a message-persisting
/// method (`requires_replay()`); it is harmless (a no-op on garbage) for
/// the others, and real deployments would run it unconditionally.
pub fn recover(
    image: &Image,
    machine: &Layout,
    log: &LogLayout,
    mode: AppendMode,
    replay: bool,
    scanner: &dyn Scanner,
) -> RecoveryResult {
    // Work on a mutable copy of the PM contents.
    let mut pm = image.read(0, image.pm_size() as usize).to_vec();
    let mut replayed = 0;

    if replay {
        // Collect surviving, integrity-valid messages from the ring.
        let mut msgs = Vec::new();
        for slot in 0..machine.rq_count {
            let addr = machine.rqwrb_slot_addr(slot);
            if addr >= image.pm_size() {
                continue; // DRAM-resident ring: nothing survives anyway
            }
            let buf =
                &pm[addr as usize..(addr + machine.rq_slot_bytes) as usize];
            if let Ok(msg) = wire::decode(buf) {
                msgs.push(msg);
            }
        }
        // Apply in message order (append order): later messages win.
        msgs.sort_by_key(|m| m.msg_seq);
        for m in &msgs {
            for u in &m.updates {
                let a = u.target as usize;
                if u.target + u.data.len() as u64 <= pm.len() as u64 {
                    pm[a..a + u.data.len()].copy_from_slice(&u.data);
                }
            }
            replayed += 1;
        }
    }

    let log_bytes = (log.capacity as usize) * RECORD_BYTES;
    let records = &pm[log.base as usize..log.base as usize + log_bytes];

    match mode {
        AppendMode::Singleton => {
            let (_, tail) = scanner.scan(records);
            RecoveryResult {
                recovered: tail,
                replayed,
                tail_ptr: None,
                records: records[..tail as usize * RECORD_BYTES].to_vec(),
            }
        }
        AppendMode::Compound => {
            let tail_ptr = u64::from_le_bytes(
                pm[log.tail_addr as usize..log.tail_addr as usize + 8]
                    .try_into()
                    .unwrap(),
            );
            let claimed = tail_ptr.min(log.capacity);
            let covered = &records[..claimed as usize * RECORD_BYTES];
            // Verify the chain the tail pointer claims; a torn suffix
            // clamps the durable prefix.
            let recovered = scanner.verify_chain(covered, 0);
            RecoveryResult {
                recovered,
                replayed,
                tail_ptr: Some(tail_ptr),
                records: covered[..recovered as usize * RECORD_BYTES].to_vec(),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::remotelog::log::{make_record, APP_WORDS};

    fn log_image(n: u64) -> Vec<u8> {
        let mut buf = Vec::new();
        for seq in 0..n {
            buf.extend_from_slice(&make_record(seq, &[seq as u32; APP_WORDS]));
        }
        buf
    }

    #[test]
    fn rust_scanner_full_valid() {
        let buf = log_image(10);
        let (valid, tail) = RustScanner.scan(&buf);
        assert_eq!(tail, 10);
        assert!(valid.iter().all(|&v| v));
    }

    #[test]
    fn rust_scanner_stops_at_first_invalid() {
        let mut buf = log_image(10);
        buf[5 * RECORD_BYTES + 3] ^= 0xFF;
        let (valid, tail) = RustScanner.scan(&buf);
        assert_eq!(tail, 5);
        assert!(!valid[5]);
        assert!(valid[6]); // later records still checksum-valid
    }

    #[test]
    fn chain_verify_catches_seq_gap() {
        let mut buf = log_image(4);
        // Replace record 2 with a valid record bearing the wrong seq.
        let wrong = make_record(7, &[0; APP_WORDS]);
        buf[2 * RECORD_BYTES..3 * RECORD_BYTES].copy_from_slice(&wrong);
        assert_eq!(RustScanner.verify_chain(&buf, 0), 2);
    }

    #[test]
    fn chain_verify_respects_base() {
        let mut buf = Vec::new();
        for seq in 5..9u64 {
            buf.extend_from_slice(&make_record(seq, &[0; APP_WORDS]));
        }
        assert_eq!(RustScanner.verify_chain(&buf, 5), 4);
        assert_eq!(RustScanner.verify_chain(&buf, 6), 0);
    }

    #[test]
    fn empty_log_recovers_zero() {
        let (_, tail) = RustScanner.scan(&[]);
        assert_eq!(tail, 0);
    }
}
