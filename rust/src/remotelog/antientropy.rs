//! Replica anti-entropy: locate where a primary's and a replica's logs
//! diverge using per-segment digests instead of byte comparison — the
//! re-synchronization subsystem a log-replication deployment needs after
//! failover (only diverging segments are re-shipped).
//!
//! The digest spec matches `python/compile/kernels/digest.py`: Fletcher
//! over each flattened [`SEG_RECORDS`]-record segment. The rust mirror
//! here is the hot-path implementation; `Runtime::segment_digests` runs
//! the same computation through the AOT Pallas kernel, and the
//! integration tests pin the two together.

use crate::integrity::fletcher_words;
use crate::remotelog::log::RECORD_BYTES;

/// Records per digest segment (matches kernels/digest.py::SEG_RECORDS).
pub const SEG_RECORDS: usize = 64;
/// Bytes per anti-entropy segment.
pub const SEG_BYTES: usize = SEG_RECORDS * RECORD_BYTES;

/// Rust-mirror segment digests over a whole number of segments.
pub fn segment_digests(records: &[u8]) -> Vec<(u32, u32)> {
    assert_eq!(records.len() % SEG_BYTES, 0, "partial segment");
    records
        .chunks_exact(SEG_BYTES)
        .map(|seg| {
            let words: Vec<u32> = seg
                .chunks_exact(4)
                .map(|c| u32::from_le_bytes(c.try_into().unwrap()))
                .collect();
            fletcher_words(&words)
        })
        .collect()
}

/// Compare two logs (padded to segment granularity by the caller) and
/// return the indices of diverging segments.
pub fn diverging_segments(primary: &[u8], replica: &[u8]) -> Vec<usize> {
    assert_eq!(primary.len(), replica.len(), "logs must be same length");
    let a = segment_digests(primary);
    let b = segment_digests(replica);
    a.iter()
        .zip(&b)
        .enumerate()
        .filter_map(|(i, (x, y))| (x != y).then_some(i))
        .collect()
}

/// Re-synchronize: overwrite the replica's diverging segments with the
/// primary's bytes; returns the number of segments shipped.
pub fn resync(primary: &[u8], replica: &mut [u8]) -> usize {
    let diverged = diverging_segments(primary, replica);
    for &s in &diverged {
        replica[s * SEG_BYTES..(s + 1) * SEG_BYTES]
            .copy_from_slice(&primary[s * SEG_BYTES..(s + 1) * SEG_BYTES]);
    }
    diverged.len()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::remotelog::log::{make_record, APP_WORDS};
    use crate::util::rng::SplitMix64;

    fn log(n: usize, seed: u64) -> Vec<u8> {
        let mut r = SplitMix64::new(seed);
        let mut out = Vec::with_capacity(n * RECORD_BYTES);
        for s in 0..n {
            let mut app = [0u32; APP_WORDS];
            for w in &mut app {
                *w = r.next_u32();
            }
            out.extend_from_slice(&make_record(s as u64, &app));
        }
        out
    }

    #[test]
    fn identical_logs_no_divergence() {
        let a = log(4 * SEG_RECORDS, 1);
        assert!(diverging_segments(&a, &a.clone()).is_empty());
    }

    #[test]
    fn single_byte_divergence_located() {
        let a = log(8 * SEG_RECORDS, 2);
        let mut b = a.clone();
        b[5 * SEG_BYTES + 100] ^= 1;
        assert_eq!(diverging_segments(&a, &b), vec![5]);
    }

    #[test]
    fn multiple_divergences_located() {
        let a = log(8 * SEG_RECORDS, 3);
        let mut b = a.clone();
        b[0] ^= 0xFF;
        b[7 * SEG_BYTES + 1] ^= 0x0F;
        assert_eq!(diverging_segments(&a, &b), vec![0, 7]);
    }

    #[test]
    fn resync_restores_equality() {
        let a = log(6 * SEG_RECORDS, 4);
        let mut b = log(6 * SEG_RECORDS, 5); // totally different
        let shipped = resync(&a, &mut b);
        assert_eq!(shipped, 6);
        assert_eq!(a, b);
        assert_eq!(resync(&a, &mut b), 0); // idempotent
    }

    #[test]
    fn empty_logs_digest_and_compare_to_nothing() {
        // A rejoining replica with no history yet: zero segments, zero
        // divergence, zero bytes shipped — not a panic.
        assert!(segment_digests(&[]).is_empty());
        assert!(diverging_segments(&[], &[]).is_empty());
        let mut empty: Vec<u8> = Vec::new();
        assert_eq!(resync(&[], &mut empty), 0);
    }

    #[test]
    #[should_panic(expected = "partial segment")]
    fn trailing_partial_segment_is_rejected() {
        // Callers must pad to segment granularity; a ragged tail would
        // silently fall out of chunks_exact and never be compared.
        let a = log(SEG_RECORDS, 7);
        segment_digests(&a[..SEG_BYTES - RECORD_BYTES]);
    }

    #[test]
    fn identical_images_resync_is_a_no_op() {
        let a = log(4 * SEG_RECORDS, 8);
        let mut b = a.clone();
        assert_eq!(resync(&a, &mut b), 0, "nothing to ship");
        assert_eq!(a, b, "a no-op resync must not touch the replica");
    }

    #[test]
    fn record_swap_within_segment_detected() {
        let a = log(SEG_RECORDS, 6);
        let mut b = a.clone();
        // Swap two records (each individually checksum-valid).
        let (r0, r1) = (0, 1);
        let mut tmp = [0u8; RECORD_BYTES];
        tmp.copy_from_slice(&b[r0 * RECORD_BYTES..(r0 + 1) * RECORD_BYTES]);
        let r1_bytes: Vec<u8> =
            b[r1 * RECORD_BYTES..(r1 + 1) * RECORD_BYTES].to_vec();
        b[r0 * RECORD_BYTES..(r0 + 1) * RECORD_BYTES]
            .copy_from_slice(&r1_bytes);
        b[r1 * RECORD_BYTES..(r1 + 1) * RECORD_BYTES].copy_from_slice(&tmp);
        assert_eq!(diverging_segments(&a, &b), vec![0]);
    }
}
