//! Seeded long-horizon soak campaign over a hostile network.
//!
//! [`run_txn_soak`] drives the group-commit transactional workload
//! ([`super::pipeline::run_txn_grouped`]) with the full hostile-network
//! stack engaged: per-QP seeded drop/jitter/duplicate faults
//! ([`crate::fabric::faults`]), scheduled partition windows, responder
//! **churn** (a shard reboots mid-workload, losing its unpersisted
//! writes, and is caught up by anti-entropy resync before serving
//! again), and every wait routed through the retry/backoff engine
//! ([`crate::persist::retry`]) so each transaction either completes or
//! aborts cleanly — never half-acks.
//!
//! After the run, [`soak_check`] replays the crash machinery at every
//! adversarial instant: acked ⇒ recovered, all-or-nothing across
//! shards, record integrity, and whole-group commit boundaries. A
//! failing configuration is greedily shrunk ([`shrink_soak_failure`])
//! to a minimal still-failing fault schedule and printed as a
//! replayable `rpmem soak` seed line ([`replay_line`]).
//!
//! With a benign [`FaultPlan`] and `max_group == 1` the runner replays
//! [`super::pipeline::run_txn_multi_shard`] bit-for-bit (no fault model
//! attached, no RNG draws, the retry probe is a pure read) — asserted
//! by the tests below, so the hostile path can never drift from the
//! calibrated one.

use crate::fabric::engine::Fabric;
use crate::fabric::faults::NetworkModel;
use crate::fabric::sharded::ShardedFabric;
use crate::fabric::timing::{Nanos, TimingModel};
use crate::persist::config::ServerConfig;
use crate::persist::exec::{Update, WaitPoint};
use crate::persist::failover::{witness_for, DecisionPair};
use crate::persist::groupcommit::{
    post_decision_group, post_decision_group_replicated, GroupCommitOpts,
    GroupScheduler, PlannedGroup,
};
use crate::persist::method::Primary;
use crate::persist::planner::plan_compound;
use crate::persist::retry::{
    await_pair_with_retry, await_with_retry, RetryPolicy,
};
use crate::persist::txn::{
    plan_txn_method, post_commit, post_prepare, recover_decisions,
    sync_clock, CommitFlip, DecisionScan, IntentRecord,
};
use crate::remotelog::antientropy::{diverging_segments, SEG_BYTES};
use crate::remotelog::log::{make_record, RECORD_BYTES};
use crate::remotelog::pipeline::{
    check_txn_crash_at_scanned, sweep_instants, txn_fabric_and_clients,
    txn_payload, GroupRunResult, TxnClient, TxnCrashReport, TxnOracle,
    TxnRun,
};
use crate::remotelog::recovery::Scanner;
use crate::util::stats::Histogram;

/// One soak run's fault schedule. All-defaults ([`FaultPlan::none`])
/// injects nothing and leaves the run bit-for-bit fault-free.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FaultPlan {
    /// Doorbell-train drop rate, per mille ([`NetworkModel`]).
    pub drop_per_mille: u32,
    /// Max extra per-op wire latency (uniform in `[0, jitter_ns]`).
    pub jitter_ns: Nanos,
    /// Update-payload redelivery rate, per mille.
    pub duplicate_per_mille: u32,
    /// `(round, duration_ns)`: at the start of wave `round`, the witness
    /// shard becomes unreachable for `duration_ns` of virtual time.
    pub partition: Option<(u64, Nanos)>,
    /// `(round, duration_ns)`: at the start of wave `round`, the last
    /// shard **reboots** — it is unreachable for `duration_ns`, loses
    /// every write not yet persistent, and rejoins only after
    /// anti-entropy resync + tail catch-up restore its log image.
    pub churn: Option<(u64, Nanos)>,
}

impl FaultPlan {
    /// No faults at all: the runner attaches no model and perturbs
    /// nothing.
    pub fn none() -> Self {
        FaultPlan {
            drop_per_mille: 0,
            jitter_ns: 0,
            duplicate_per_mille: 0,
            partition: None,
            churn: None,
        }
    }

    /// True when the plan schedules nothing.
    pub fn is_benign(&self) -> bool {
        self.drop_per_mille == 0
            && self.jitter_ns == 0
            && self.duplicate_per_mille == 0
            && self.partition.is_none()
            && self.churn.is_none()
    }
}

/// Options for a soak run: the group-commit workload knobs plus the
/// fault schedule and retry policy.
#[derive(Debug, Clone, Copy)]
pub struct SoakOpts {
    /// Independent coordinators; client `c`'s decision ring lives on QP
    /// `c % shards`.
    pub clients: usize,
    /// QPs; every transaction spans ALL of them.
    pub shards: usize,
    /// Transactions per client.
    pub txns_per_client: u64,
    /// Log slots (= intent/decision slots) per client per shard.
    pub capacity: u64,
    /// Seed for engine jitter AND all fault draws.
    pub seed: u64,
    /// Mirror decisions to the witness QP ([`crate::persist::failover`]).
    pub replicate: bool,
    /// Group-commit policy ([`crate::persist::groupcommit`]).
    pub group: GroupCommitOpts,
    /// The fault schedule.
    pub plan: FaultPlan,
    /// Timeout/backoff policy for every retried wait.
    pub retry: RetryPolicy,
    /// Negative control: on timeout, ack WITHOUT re-posting (a broken
    /// retry implementation). Must make the campaign fail — a soak
    /// harness that can't catch this proves nothing.
    pub broken_retry: bool,
}

impl Default for SoakOpts {
    fn default() -> Self {
        SoakOpts {
            clients: 2,
            shards: 2,
            txns_per_client: 16,
            capacity: 32,
            seed: 7,
            replicate: false,
            group: GroupCommitOpts::default(),
            plan: FaultPlan::none(),
            retry: RetryPolicy::default(),
            broken_retry: false,
        }
    }
}

/// What the fault stack actually did during a soak run — a passing
/// campaign must show nonzero counters here, or it tested nothing.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct SoakStats {
    /// Re-posts issued by the retry engine across all waits.
    pub retries: u64,
    /// Ops dropped on the wire (whole trains count each op).
    pub dropped_ops: u64,
    /// Update payloads redelivered.
    pub duplicated: u64,
    /// Anti-entropy segments shipped to rejoining shards.
    pub resync_segments: u64,
    /// Writes a rebooting shard lost (posted but not yet persistent).
    pub discarded_writes: u64,
    /// Shard reboot (leave + rejoin) events.
    pub churn_events: u64,
    /// Transactions the run aborted after retry exhaustion (presumed
    /// abort: prepared state is garbage-collected by recovery, never
    /// acked, never counted).
    pub aborted_txns: u64,
}

/// Crash-invariant verdict of a soak run.
#[derive(Debug, Clone, Copy, Default)]
pub struct SoakReport {
    /// Durability / atomicity / integrity over the full crash sweep.
    pub crash: TxnCrashReport,
    /// Crash instants where a recovered committed prefix fell off a
    /// group boundary (partial group = torn group commit).
    pub boundary_violations: u64,
}

impl SoakReport {
    /// Every invariant held at every crash instant?
    pub fn clean(&self) -> bool {
        self.crash.clean() && self.boundary_violations == 0
    }
}

/// Fabricated-ack wait for the broken-retry negative control: if the
/// point is never coming, charge the timeout and "ack" anyway, without
/// re-posting. The crash sweep must catch the resulting loss.
fn broken_await(
    fab: &mut Fabric,
    policy: &RetryPolicy,
    wp: WaitPoint,
) -> Option<(Nanos, u32)> {
    if wp.try_ready_at(fab).is_some() {
        return Some((wp.wait(fab), 0));
    }
    let t = fab.now() + policy.timeout_ns;
    sync_clock(fab, t);
    Some((t, 0))
}

/// Pair-flavoured [`broken_await`].
fn broken_await_pair(
    coord: &mut Fabric,
    witness: &mut Fabric,
    policy: &RetryPolicy,
    pair: DecisionPair,
) -> Option<(Nanos, u32)> {
    if pair.primary.try_ready_at(coord).is_some()
        && pair.witness.try_ready_at(witness).is_some()
    {
        return Some((pair.wait(coord, witness), 0));
    }
    let t = coord.now().max(witness.now()) + policy.timeout_ns;
    sync_clock(coord, t);
    sync_clock(witness, t);
    Some((t, 0))
}

/// Reboot shard `s` at the current makespan: unreachable for `dur`,
/// every not-yet-persistent write lost, then — at the rejoin instant —
/// anti-entropy resync ships any log segment diverging from the acked
/// oracle state ([`crate::remotelog::antientropy`]) and a tail
/// catch-up write restores each client's tail pointer, so the shard
/// serves a consistent image again. Runs at a wave boundary only: no
/// prepare is in flight, so the acked oracle IS the expected log.
fn churn_shard(
    fabric: &mut ShardedFabric,
    clients: &[TxnClient],
    s: usize,
    dur: Nanos,
    capacity: u64,
    stats: &mut SoakStats,
) {
    let p0 = fabric.makespan();
    sync_clock(fabric.qp_mut(s), p0);
    fabric.partition_shard(s, p0, p0 + dur);
    let pd = fabric.qp(s).cfg.pdomain;
    stats.discarded_writes +=
        fabric.qp_mut(s).mem.discard_after(p0, pd) as u64;
    let rejoin = p0 + dur;
    let region = capacity as usize * RECORD_BYTES;
    let buf_len = region.div_ceil(SEG_BYTES) * SEG_BYTES;
    for client in clients {
        // Expected image: exactly the acked transactions' records
        // (presumed abort: anything else in the region is garbage a
        // rejoining replica must NOT serve).
        let mut expected = vec![0u8; buf_len];
        for x in &client.txns {
            let off =
                (x.txn_id % capacity) as usize * RECORD_BYTES;
            expected[off..off + RECORD_BYTES]
                .copy_from_slice(&x.records[s]);
        }
        let mut replica = vec![0u8; buf_len];
        {
            let img = fabric.qp(s).mem.crash_image(rejoin, pd);
            replica[..region]
                .copy_from_slice(img.read(client.logs[s].base, region));
        }
        for &seg in &diverging_segments(&expected, &replica) {
            let start = seg * SEG_BYTES;
            let end = (start + SEG_BYTES).min(region);
            fabric.qp_mut(s).record_cpu_write(
                client.logs[s].base + start as u64,
                expected[start..end].to_vec(),
                rejoin,
            );
            stats.resync_segments += 1;
        }
        let tail = client.txns.len() as u64;
        fabric.qp_mut(s).record_cpu_write(
            client.logs[s].tail_addr,
            tail.to_le_bytes().to_vec(),
            rejoin,
        );
    }
    stats.churn_events += 1;
}

/// Drive the group-commit transactional workload under the fault plan,
/// with every wait routed through the retry engine. Always records
/// (the run exists to be crash-checked). On retry exhaustion the run
/// aborts cleanly: the failing transaction and everything after it are
/// never acked and never entered in the oracle — the crash sweep then
/// proves recovery treats them as aborted (presumed abort), not torn.
pub fn run_txn_soak(
    cfg: ServerConfig,
    timing: TimingModel,
    primary: Primary,
    opts: &SoakOpts,
) -> (TxnRun, GroupRunResult, SoakStats) {
    assert!(opts.clients >= 1 && opts.shards >= 1);
    assert!(opts.group.max_group >= 1);
    assert!(
        opts.txns_per_client <= opts.capacity,
        "ring wraparound would invalidate the crash oracle"
    );
    assert!(
        opts.group.max_group as u64 <= opts.capacity,
        "a group must fit the decision ring"
    );
    assert!(
        !opts.replicate || opts.shards >= 2,
        "decision replication needs a second shard"
    );
    let method = plan_txn_method(&cfg, primary);
    let compound_method = plan_compound(&cfg, primary, 8);
    let (mut fabric, mut clients) = txn_fabric_and_clients(
        cfg,
        timing,
        opts.clients,
        opts.shards,
        opts.capacity,
        opts.seed,
        true,
    );
    if !opts.plan.is_benign() {
        let model = NetworkModel::new(opts.seed)
            .with_drop(opts.plan.drop_per_mille)
            .with_jitter(opts.plan.jitter_ns)
            .with_duplicates(opts.plan.duplicate_per_mille);
        fabric.attach_faults(&model);
    }

    let total = opts.txns_per_client;
    let mut msg_seq = 0u32;
    let mut decision_ns_total = 0u64;
    let mut group_sizes: Vec<Vec<(u64, u32)>> =
        vec![Vec::new(); opts.clients];
    let mut stats = SoakStats::default();
    let mut aborted = false;

    let mut round = 0u64;
    let mut wave_first = 0u64;
    while wave_first < total && !aborted {
        // Scheduled faults fire at wave boundaries (no prepare in
        // flight; acked state is exactly the oracle).
        if let Some((r, dur)) = opts.plan.partition {
            if round == r {
                let s = if opts.shards >= 2 {
                    witness_for(0, opts.shards)
                } else {
                    0
                };
                let p0 = fabric.makespan();
                sync_clock(fabric.qp_mut(s), p0);
                fabric.partition_shard(s, p0, p0 + dur);
            }
        }
        if let Some((r, dur)) = opts.plan.churn {
            if round == r {
                churn_shard(
                    &mut fabric,
                    &clients,
                    opts.shards - 1,
                    dur,
                    opts.capacity,
                    &mut stats,
                );
            }
        }

        let wave =
            (opts.group.max_group as u64).min(total - wave_first) as usize;

        // PREPARE the whole wave — identical posting order and message
        // sequencing to run_txn_grouped, remembering each train's seq
        // for idempotent re-posts.
        let mut starts = vec![vec![0u64; wave]; opts.clients];
        let mut recs: Vec<Vec<Vec<[u8; RECORD_BYTES]>>> =
            vec![Vec::with_capacity(wave); opts.clients];
        let mut wpss: Vec<Vec<Vec<(WaitPoint, u32)>>> =
            vec![Vec::with_capacity(wave); opts.clients];
        for w in 0..wave {
            let txn = wave_first + w as u64;
            for c in 0..opts.clients {
                let client = &clients[c];
                starts[c][w] = (0..opts.shards)
                    .map(|s| fabric.qp(s).now())
                    .max()
                    .unwrap_or(0);
                let mut records = Vec::with_capacity(opts.shards);
                let mut wps = Vec::with_capacity(opts.shards);
                for s in 0..opts.shards {
                    let record = make_record(
                        txn,
                        &txn_payload(c as u64, s as u64, txn),
                    );
                    let a = Update::new(
                        client.logs[s].slot_addr(txn),
                        record.to_vec(),
                    );
                    records.push(record);
                    msg_seq = msg_seq.wrapping_add(4);
                    let intent = IntentRecord {
                        txn_id: txn,
                        shard: s as u32,
                        flips: vec![CommitFlip {
                            addr: client.logs[s].tail_addr,
                            value: txn + 1,
                        }],
                    };
                    wps.push((
                        post_prepare(
                            fabric.qp_mut(s),
                            method,
                            std::slice::from_ref(&a),
                            &intent,
                            client.intents[s].addr(txn),
                            msg_seq,
                        ),
                        msg_seq,
                    ));
                }
                recs[c].push(records);
                wpss[c].push(wps);
            }
        }
        // Await every PREPARE through the retry engine. Exhaustion
        // truncates the wave at the first failed transaction: earlier
        // ones proceed to DECIDE, later ones are presumed aborted.
        let mut prepared = vec![vec![0u64; wave]; opts.clients];
        let mut trunc = wave;
        'prep: for w in 0..wave {
            let txn = wave_first + w as u64;
            for c in 0..opts.clients {
                for s in 0..opts.shards {
                    let (wp, seq) = wpss[c][w][s];
                    let rec = recs[c][w][s];
                    let slot_addr = clients[c].logs[s].slot_addr(txn);
                    let tail_addr = clients[c].logs[s].tail_addr;
                    let intent_addr = clients[c].intents[s].addr(txn);
                    let shard = s as u32;
                    let out = if opts.broken_retry {
                        broken_await(fabric.qp_mut(s), &opts.retry, wp)
                    } else {
                        await_with_retry(
                            fabric.qp_mut(s),
                            &opts.retry,
                            wp,
                            move |f| {
                                let a = Update::new(
                                    slot_addr,
                                    rec.to_vec(),
                                );
                                let intent = IntentRecord {
                                    txn_id: txn,
                                    shard,
                                    flips: vec![CommitFlip {
                                        addr: tail_addr,
                                        value: txn + 1,
                                    }],
                                };
                                post_prepare(
                                    f,
                                    method,
                                    std::slice::from_ref(&a),
                                    &intent,
                                    intent_addr,
                                    seq,
                                )
                            },
                        )
                    };
                    match out {
                        Some((t, attempts)) => {
                            stats.retries += attempts as u64;
                            prepared[c][w] = prepared[c][w].max(t);
                        }
                        None => {
                            trunc = w;
                            aborted = true;
                            break 'prep;
                        }
                    }
                }
            }
        }

        // Schedule the surviving prefix of the wave into groups.
        let mut groups: Vec<Vec<PlannedGroup>> =
            Vec::with_capacity(opts.clients);
        for c in 0..opts.clients {
            let mut sched = GroupScheduler::new(opts.group);
            let mut gs = Vec::new();
            for w in 0..trunc {
                let txn = wave_first + w as u64;
                if let Some(g) = sched.offer(txn, prepared[c][w]) {
                    gs.push(g);
                }
            }
            if let Some(g) = sched.drain() {
                gs.push(g);
            }
            groups.push(gs);
        }

        // GROUP DECIDE: post every client's trains (identical order to
        // run_txn_grouped), then await each through the retry engine.
        let mut dwps: Vec<Vec<(WaitPoint, Option<WaitPoint>, u32, u32)>> =
            Vec::with_capacity(opts.clients);
        for c in 0..opts.clients {
            let qp = clients[c].coord_qp;
            let mut v = Vec::with_capacity(groups[c].len());
            for g in &groups[c] {
                if opts.replicate {
                    let wq = clients[c].witness_qp;
                    let (cseq, wseq) =
                        (msg_seq.wrapping_add(1), msg_seq.wrapping_add(2));
                    msg_seq = msg_seq.wrapping_add(2);
                    let (coord, wit) = fabric.qp_pair_mut(qp, wq);
                    let pair = post_decision_group_replicated(
                        coord,
                        wit,
                        method,
                        g.first,
                        g.len,
                        &clients[c].decisions,
                        &clients[c].replicas,
                        g.release_at,
                        cseq,
                        wseq,
                    );
                    v.push((pair.primary, Some(pair.witness), cseq, wseq));
                } else {
                    msg_seq = msg_seq.wrapping_add(1);
                    v.push((
                        post_decision_group(
                            fabric.qp_mut(qp),
                            method,
                            g.first,
                            g.len,
                            &clients[c].decisions,
                            g.release_at,
                            msg_seq,
                        ),
                        None,
                        msg_seq,
                        0,
                    ));
                }
            }
            dwps.push(v);
        }
        let mut gacks: Vec<Vec<Nanos>> = vec![Vec::new(); opts.clients];
        for c in 0..opts.clients {
            let qp = clients[c].coord_qp;
            let wq = clients[c].witness_qp;
            for (gi, g) in groups[c].iter().enumerate() {
                let (wp, rep, cseq, wseq) = dwps[c][gi];
                let (first, len) = (g.first, g.len);
                let out = if let Some(repwp) = rep {
                    let pair =
                        DecisionPair { primary: wp, witness: repwp };
                    let decisions = &clients[c].decisions;
                    let replicas = &clients[c].replicas;
                    let (coord, wit) = fabric.qp_pair_mut(qp, wq);
                    if opts.broken_retry {
                        broken_await_pair(coord, wit, &opts.retry, pair)
                    } else {
                        await_pair_with_retry(
                            coord,
                            wit,
                            &opts.retry,
                            pair,
                            |co, wi, resume| {
                                post_decision_group_replicated(
                                    co, wi, method, first, len,
                                    decisions, replicas, resume, cseq,
                                    wseq,
                                )
                            },
                        )
                    }
                } else if opts.broken_retry {
                    broken_await(fabric.qp_mut(qp), &opts.retry, wp)
                } else {
                    let ring = &clients[c].decisions;
                    await_with_retry(
                        fabric.qp_mut(qp),
                        &opts.retry,
                        wp,
                        |f| {
                            let nb = f.now();
                            post_decision_group(
                                f, method, first, len, ring, nb, cseq,
                            )
                        },
                    )
                };
                match out {
                    Some((t, attempts)) => {
                        stats.retries += attempts as u64;
                        decision_ns_total += t - g.release_at;
                        gacks[c].push(t);
                    }
                    None => {
                        // This coordinator acks nothing from here on;
                        // presumed abort covers the undecided tail.
                        aborted = true;
                        break;
                    }
                }
            }
        }

        // GROUP COMMIT for acked groups only (lazy, never awaited —
        // recovery roll-forward heals in-flight markers).
        for c in 0..opts.clients {
            for (gi, g) in
                groups[c].iter().enumerate().take(gacks[c].len())
            {
                for s in 0..opts.shards {
                    sync_clock(fabric.qp_mut(s), gacks[c][gi]);
                    msg_seq = msg_seq.wrapping_add(g.len as u32);
                    let flips: Vec<CommitFlip> = (0..g.len as u64)
                        .map(|k| CommitFlip {
                            addr: clients[c].logs[s].tail_addr,
                            value: g.first + k + 1,
                        })
                        .collect();
                    let _ = post_commit(
                        fabric.qp_mut(s),
                        method,
                        &flips,
                        msg_seq,
                    );
                }
            }
        }

        // Book-keeping for acked transactions only.
        for c in 0..opts.clients {
            let mut acked = Vec::new();
            for (gi, g) in
                groups[c].iter().enumerate().take(gacks[c].len())
            {
                group_sizes[c].push((g.first, g.len as u32));
                for _ in 0..g.len {
                    acked.push(gacks[c][gi]);
                }
            }
            for (w, &t) in acked.iter().enumerate() {
                clients[c].latencies.record(t - starts[c][w]);
                clients[c].txns.push(TxnOracle {
                    txn_id: wave_first + w as u64,
                    records: recs[c][w].clone(),
                    prepared_at: prepared[c][w],
                    acked_at: t,
                });
            }
        }

        wave_first += wave as u64;
        round += 1;
    }

    for s in 0..opts.shards {
        if let Some(m) = fabric.qp(s).faults() {
            stats.dropped_ops += m.stats.dropped_ops;
            stats.duplicated += m.stats.duplicated;
        }
    }
    let acked_total: u64 =
        clients.iter().map(|c| c.txns.len() as u64).sum();
    stats.aborted_txns = total * opts.clients as u64 - acked_total;

    let span_ns = fabric.makespan();
    let mut summary = Histogram::new();
    for c in &clients {
        summary.merge(&c.latencies);
    }
    let result = GroupRunResult {
        clients: opts.clients,
        shards: opts.shards,
        txns: acked_total,
        groups: group_sizes.iter().map(|g| g.len() as u64).sum(),
        span_ns,
        mean_latency_ns: summary.summary().mean(),
        p99_latency_ns: summary.quantile(0.99),
        decision_ns_total,
        group_sizes,
    };
    let run = TxnRun {
        fabric,
        clients,
        atomic: true,
        replicate: opts.replicate,
        method,
        compound_method,
    };
    (run, result, stats)
}

/// Count crash instants where a recovered committed prefix falls off a
/// group boundary — the non-panicking sibling of
/// [`super::pipeline::assert_group_boundaries`], so the soak campaign
/// can report violations alongside the crash report instead of dying
/// on the first one.
pub fn group_boundary_violations(
    run: &TxnRun,
    res: &GroupRunResult,
    instants: &[Nanos],
) -> u64 {
    let mut violations = 0;
    for (ci, client) in run.clients.iter().enumerate() {
        let bounds = res.boundaries(ci);
        for &t in instants {
            let mut rings = vec![(client.coord_qp, &client.decisions)];
            if run.replicate {
                rings.push((client.witness_qp, &client.replicas));
            }
            for (qp, ring) in rings {
                let pd = run.fabric.qp(qp).cfg.pdomain;
                let img = run.fabric.qp(qp).mem.crash_image(t, pd);
                if !bounds.contains(&recover_decisions(&img, ring)) {
                    violations += 1;
                }
            }
        }
    }
    violations
}

/// Full invariant sweep over a soak run: durability (acked ⇒
/// recovered), atomicity (all-or-nothing across shards), integrity
/// (records match the oracle), and whole-group boundaries, at
/// `uniform_points` seeded instants plus the adversarial instants
/// around every prepare/ack.
pub fn soak_check(
    run: &TxnRun,
    res: &GroupRunResult,
    uniform_points: u64,
    seed: u64,
    scanner: &dyn Scanner,
) -> SoakReport {
    let instants = sweep_instants(run, uniform_points, seed);
    let mut scans = vec![DecisionScan::default(); run.clients.len()];
    let mut crash = TxnCrashReport::default();
    for &t in &instants {
        crash.merge(&check_txn_crash_at_scanned(
            run, t, None, scanner, &mut scans,
        ));
    }
    SoakReport {
        crash,
        boundary_violations: group_boundary_violations(
            run, res, &instants,
        ),
    }
}

/// Run + check one soak case. The sweep seed is derived from the run
/// seed so a replayed seed line reproduces the identical verdict.
pub fn run_soak_case(
    cfg: ServerConfig,
    timing: TimingModel,
    primary: Primary,
    opts: &SoakOpts,
    uniform_points: u64,
    scanner: &dyn Scanner,
) -> (GroupRunResult, SoakStats, SoakReport) {
    let (run, res, stats) = run_txn_soak(cfg, timing, primary, opts);
    let report =
        soak_check(&run, &res, uniform_points, opts.seed ^ 0x50AC, scanner);
    (res, stats, report)
}

/// Greedily shrink a failing soak configuration: try zeroing each fault
/// knob, dropping each scheduled event, and halving the workload; keep
/// any mutation that still fails, until no single mutation does. The
/// result is the minimal repro to print via [`replay_line`].
pub fn shrink_soak_failure(
    cfg: ServerConfig,
    timing: &TimingModel,
    primary: Primary,
    opts: &SoakOpts,
    uniform_points: u64,
    scanner: &dyn Scanner,
) -> SoakOpts {
    let fails = |o: &SoakOpts| {
        let (_, _, report) = run_soak_case(
            cfg,
            timing.clone(),
            primary,
            o,
            uniform_points,
            scanner,
        );
        !report.clean()
    };
    let mut best = *opts;
    loop {
        let mut candidates: Vec<SoakOpts> = Vec::new();
        if best.plan.drop_per_mille > 0 {
            let mut o = best;
            o.plan.drop_per_mille = 0;
            candidates.push(o);
        }
        if best.plan.jitter_ns > 0 {
            let mut o = best;
            o.plan.jitter_ns = 0;
            candidates.push(o);
        }
        if best.plan.duplicate_per_mille > 0 {
            let mut o = best;
            o.plan.duplicate_per_mille = 0;
            candidates.push(o);
        }
        if best.plan.partition.is_some() {
            let mut o = best;
            o.plan.partition = None;
            candidates.push(o);
        }
        if best.plan.churn.is_some() {
            let mut o = best;
            o.plan.churn = None;
            candidates.push(o);
        }
        if best.txns_per_client > 1 {
            let mut o = best;
            o.txns_per_client /= 2;
            candidates.push(o);
        }
        if best.clients > 1 {
            let mut o = best;
            o.clients -= 1;
            candidates.push(o);
        }
        let mut improved = false;
        for o in candidates {
            if fails(&o) {
                best = o;
                improved = true;
                break;
            }
        }
        if !improved {
            return best;
        }
    }
}

/// Render a soak configuration as the `rpmem soak` invocation that
/// replays it exactly — the seed line printed for every shrunk failure.
pub fn replay_line(config: usize, opts: &SoakOpts) -> String {
    use std::fmt::Write as _;
    let mut s = format!(
        "rpmem soak --configs {config} --seeds {} --clients {} \
         --shards {} --txns {} --group {}",
        opts.seed,
        opts.clients,
        opts.shards,
        opts.txns_per_client,
        opts.group.max_group
    );
    if opts.replicate {
        s.push_str(" --replicate");
    }
    if opts.plan.drop_per_mille > 0 {
        let _ = write!(s, " --drop {}", opts.plan.drop_per_mille);
    }
    if opts.plan.jitter_ns > 0 {
        let _ = write!(s, " --jitter {}", opts.plan.jitter_ns);
    }
    if opts.plan.duplicate_per_mille > 0 {
        let _ = write!(s, " --duplicate {}", opts.plan.duplicate_per_mille);
    }
    if let Some((r, ns)) = opts.plan.partition {
        let _ =
            write!(s, " --partition-round {r} --partition-ns {ns}");
    }
    if let Some((r, ns)) = opts.plan.churn {
        let _ = write!(s, " --churn-round {r} --churn-ns {ns}");
    }
    if opts.broken_retry {
        s.push_str(" --broken-retry");
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::persist::config::{PDomain, RqwrbLoc, ServerConfig};
    use crate::remotelog::pipeline::{run_txn_multi_shard, TxnRunOpts};
    use crate::remotelog::recovery::RustScanner;

    fn mhp() -> ServerConfig {
        ServerConfig::new(PDomain::Mhp, false, RqwrbLoc::Dram)
    }

    /// The hostile fault mix every campaign-shaped test uses: drops,
    /// jitter, duplicates, one partition window, one churn event.
    fn hostile() -> FaultPlan {
        FaultPlan {
            drop_per_mille: 20,
            jitter_ns: 300,
            duplicate_per_mille: 10,
            partition: Some((1, 60_000)),
            churn: Some((2, 60_000)),
        }
    }

    #[test]
    fn zero_fault_max_group_one_replays_multi_shard_bit_for_bit() {
        let opts = SoakOpts {
            clients: 2,
            shards: 2,
            txns_per_client: 8,
            capacity: 16,
            seed: 7,
            group: GroupCommitOpts {
                max_group: 1,
                ..Default::default()
            },
            ..Default::default()
        };
        let (_, soak, stats) = run_txn_soak(
            mhp(),
            TimingModel::deterministic(),
            Primary::Write,
            &opts,
        );
        let (_, plain) = run_txn_multi_shard(
            mhp(),
            TimingModel::deterministic(),
            Primary::Write,
            &TxnRunOpts {
                clients: 2,
                shards: 2,
                txns_per_client: 8,
                capacity: 16,
                seed: 7,
                record: true,
                atomic: true,
                replicate: false,
            },
        );
        assert_eq!(soak.txns, plain.txns);
        assert_eq!(soak.span_ns, plain.span_ns);
        assert_eq!(soak.mean_latency_ns, plain.mean_latency_ns);
        assert_eq!(soak.p99_latency_ns, plain.p99_latency_ns);
        assert_eq!(soak.decision_ns_total, plain.decision_ns_total);
        assert_eq!(stats, SoakStats::default(), "benign plan must be free");
    }

    /// The full fault mix — drops, jitter, duplicates, a partition
    /// window, a churn event — with the retry engine on: every acked
    /// transaction recovers, whole groups only, and the stats prove
    /// the faults really fired.
    #[test]
    fn hostile_run_is_clean_and_faults_really_fired() {
        let opts = SoakOpts {
            clients: 2,
            shards: 3,
            txns_per_client: 12,
            capacity: 16,
            seed: 11,
            replicate: true,
            group: GroupCommitOpts {
                max_group: 4,
                ..Default::default()
            },
            plan: hostile(),
            ..Default::default()
        };
        let (res, stats, report) = run_soak_case(
            mhp(),
            TimingModel::deterministic(),
            Primary::Write,
            &opts,
            40,
            &RustScanner,
        );
        assert!(report.clean(), "hostile soak must stay clean: {report:?}");
        assert_eq!(res.txns, 24, "every transaction must have acked");
        assert_eq!(stats.aborted_txns, 0);
        assert_eq!(stats.churn_events, 1);
        assert!(
            stats.dropped_ops > 0,
            "a 2% drop rate over this run must hit something"
        );
        assert!(
            stats.retries > 0,
            "dropped trains must have been re-posted"
        );
    }

    /// Churn on a healthy log ships nothing (digests match: every acked
    /// record was persistent before the reboot) but still restores the
    /// tail pointer; the run stays clean through the rejoin.
    #[test]
    fn healthy_churn_ships_zero_segments_and_stays_clean() {
        let opts = SoakOpts {
            clients: 1,
            shards: 2,
            txns_per_client: 12,
            capacity: 16,
            seed: 3,
            group: GroupCommitOpts {
                max_group: 4,
                ..Default::default()
            },
            plan: FaultPlan {
                churn: Some((1, 50_000)),
                ..FaultPlan::none()
            },
            ..Default::default()
        };
        let (res, stats, report) = run_soak_case(
            mhp(),
            TimingModel::deterministic(),
            Primary::Write,
            &opts,
            30,
            &RustScanner,
        );
        assert!(report.clean(), "{report:?}");
        assert_eq!(res.txns, 12);
        assert_eq!(stats.churn_events, 1);
        assert_eq!(
            stats.resync_segments, 0,
            "acked-only logs are already in sync"
        );
    }

    /// Anti-entropy earns its keep when the rejoining shard's log image
    /// diverges from the acked state: an orphan record (e.g. a prepare
    /// left by an aborted transaction) is wiped back to the expected
    /// image — presumed-abort cleanup, counted in resync_segments.
    #[test]
    fn churn_resync_wipes_diverging_segments() {
        let cfg = mhp();
        let (mut fabric, clients) = txn_fabric_and_clients(
            cfg,
            TimingModel::deterministic(),
            1,
            2,
            16,
            7,
            true,
        );
        fabric.attach_faults(&NetworkModel::new(7));
        // An orphan record in shard 1's log region, persistent well
        // before the reboot so the discard doesn't remove it.
        let orphan = make_record(3, &txn_payload(0, 1, 3));
        let slot = clients[0].logs[1].slot_addr(3);
        fabric.qp_mut(1).record_cpu_write(slot, orphan.to_vec(), 10);
        sync_clock(fabric.qp_mut(1), 1_000);

        let mut stats = SoakStats::default();
        churn_shard(&mut fabric, &clients, 1, 5_000, 16, &mut stats);
        assert!(
            stats.resync_segments > 0,
            "the orphan record must diverge a segment"
        );
        // After the rejoin instant the orphan is gone: the region
        // matches the (empty) acked oracle again.
        let pd = fabric.qp(1).cfg.pdomain;
        let rejoin = fabric.qp(1).now() + 5_000;
        let img = fabric.qp(1).mem.crash_image(rejoin, pd);
        assert_eq!(
            img.read(slot, RECORD_BYTES),
            &[0u8; RECORD_BYTES][..],
            "presumed-abort cleanup must wipe the orphan"
        );
    }

    /// Negative control: a retry implementation that acks on timeout
    /// WITHOUT re-posting must make the campaign fail — otherwise the
    /// soak harness proves nothing.
    #[test]
    fn broken_retry_fails_the_campaign() {
        let opts = SoakOpts {
            clients: 2,
            shards: 2,
            txns_per_client: 8,
            capacity: 16,
            seed: 5,
            group: GroupCommitOpts {
                max_group: 4,
                ..Default::default()
            },
            plan: FaultPlan {
                drop_per_mille: 400,
                ..FaultPlan::none()
            },
            broken_retry: true,
            ..Default::default()
        };
        let (_, stats, report) = run_soak_case(
            mhp(),
            TimingModel::deterministic(),
            Primary::Write,
            &opts,
            30,
            &RustScanner,
        );
        assert!(stats.dropped_ops > 0, "40% drops must hit something");
        assert!(
            !report.clean(),
            "fabricated acks over dropped trains must violate \
             durability: {report:?}"
        );
        // The same schedule with the real retry engine is clean.
        let good = SoakOpts { broken_retry: false, ..opts };
        let (_, _, report) = run_soak_case(
            mhp(),
            TimingModel::deterministic(),
            Primary::Write,
            &good,
            30,
            &RustScanner,
        );
        assert!(report.clean(), "{report:?}");
    }

    /// Retry exhaustion aborts the run cleanly: nothing past the failed
    /// transaction acks, the crash sweep stays clean (presumed abort),
    /// and the aborted count is surfaced.
    #[test]
    fn exhaustion_aborts_cleanly_never_half_acks() {
        let opts = SoakOpts {
            clients: 1,
            shards: 2,
            txns_per_client: 6,
            capacity: 16,
            seed: 9,
            group: GroupCommitOpts {
                max_group: 2,
                ..Default::default()
            },
            // A partition far longer than the whole retry budget.
            plan: FaultPlan {
                partition: Some((0, 100_000_000)),
                ..FaultPlan::none()
            },
            retry: RetryPolicy {
                max_attempts: 2,
                ..Default::default()
            },
            ..Default::default()
        };
        let (run, res, stats) = run_txn_soak(
            mhp(),
            TimingModel::deterministic(),
            Primary::Write,
            &opts,
        );
        assert_eq!(res.txns, 0, "nothing may ack through a dead witness");
        assert_eq!(stats.aborted_txns, 6);
        let report = soak_check(&run, &res, 30, 1, &RustScanner);
        assert!(
            report.clean(),
            "aborted transactions must recover as aborted: {report:?}"
        );
    }

    /// The shrinker strips fault knobs that don't matter and keeps the
    /// one that does, ending on a minimal still-failing schedule whose
    /// replay line round-trips the failure.
    #[test]
    fn shrinker_finds_minimal_failing_schedule() {
        let noisy = SoakOpts {
            clients: 2,
            shards: 2,
            txns_per_client: 8,
            capacity: 16,
            seed: 5,
            group: GroupCommitOpts {
                max_group: 4,
                ..Default::default()
            },
            plan: FaultPlan {
                drop_per_mille: 400,
                jitter_ns: 200,
                duplicate_per_mille: 10,
                partition: None,
                churn: None,
            },
            broken_retry: true,
            ..Default::default()
        };
        let timing = TimingModel::deterministic();
        let shrunk = shrink_soak_failure(
            mhp(),
            &timing,
            Primary::Write,
            &noisy,
            20,
            &RustScanner,
        );
        // The failure needs drops + the broken retry; jitter and
        // duplicates are noise the shrinker must remove.
        assert!(shrunk.plan.drop_per_mille > 0);
        assert!(shrunk.broken_retry);
        assert_eq!(shrunk.plan.jitter_ns, 0);
        assert_eq!(shrunk.plan.duplicate_per_mille, 0);
        // Still failing, so the printed line reproduces it.
        let (_, _, report) = run_soak_case(
            mhp(),
            timing,
            Primary::Write,
            &shrunk,
            20,
            &RustScanner,
        );
        assert!(!report.clean());
        let line = replay_line(0, &shrunk);
        assert!(line.starts_with("rpmem soak --configs 0 --seeds 5"));
        assert!(line.contains("--drop 400"));
        assert!(line.contains("--broken-retry"));
        assert!(!line.contains("--jitter"));
    }
}
