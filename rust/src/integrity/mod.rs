//! Record/message integrity: the Fletcher checksum spec shared across all
//! three layers.
//!
//! This is the rust mirror of `python/compile/kernels/ref.py` — the same
//! dual-accumulator Fletcher over little-endian u32 words, mod 2^32:
//!
//! ```text
//! s1 = 1; s2 = 0
//! for w in words: s1 += w; s2 += s1      (wrapping u32)
//! ```
//!
//! `s1` starts at 1 so all-zero data never checksums to (0, 0): freshly
//! zeroed PM can never masquerade as a valid record — the property that
//! lets REMOTELOG detect its tail by checksum failure (paper §4.1). The
//! requester computes checksums here on the hot path; the recovery path
//! recomputes them through the AOT-compiled Pallas kernel, and the python
//! tests pin both to the same oracle.

/// Fletcher over u32 words. Returns (s1, s2).
#[inline]
pub fn fletcher_words(words: &[u32]) -> (u32, u32) {
    let mut s1: u32 = 1;
    let mut s2: u32 = 0;
    for &w in words {
        s1 = s1.wrapping_add(w);
        s2 = s2.wrapping_add(s1);
    }
    (s1, s2)
}

/// Fletcher over bytes, interpreted as little-endian u32 words; a partial
/// trailing word is zero-padded.
pub fn fletcher_bytes(bytes: &[u8]) -> (u32, u32) {
    let mut s1: u32 = 1;
    let mut s2: u32 = 0;
    let mut chunks = bytes.chunks_exact(4);
    for c in &mut chunks {
        s1 = s1.wrapping_add(u32::from_le_bytes(c.try_into().unwrap()));
        s2 = s2.wrapping_add(s1);
    }
    let rem = chunks.remainder();
    if !rem.is_empty() {
        let mut last = [0u8; 4];
        last[..rem.len()].copy_from_slice(rem);
        s1 = s1.wrapping_add(u32::from_le_bytes(last));
        s2 = s2.wrapping_add(s1);
    }
    (s1, s2)
}

/// Combined 64-bit digest (s2 ‖ s1) — convenient single-word form.
pub fn fletcher64(bytes: &[u8]) -> u64 {
    let (s1, s2) = fletcher_bytes(bytes);
    ((s2 as u64) << 32) | s1 as u64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matches_python_spec_zero() {
        // ref.py: zero payload of W words -> s1 = 1, s2 = W.
        let words = [0u32; 14];
        assert_eq!(fletcher_words(&words), (1, 14));
    }

    #[test]
    fn matches_python_spec_known_vector() {
        // Hand-computed: words [1, 2, 3]:
        // s1: 1->2->4->7 ; s2: 2->6->13
        assert_eq!(fletcher_words(&[1, 2, 3]), (7, 13));
    }

    #[test]
    fn wrapping_behaviour() {
        let words = [u32::MAX, u32::MAX];
        // s1: 1 + MAX = 0; + MAX = MAX. s2: 0 + 0 = 0; + MAX = MAX.
        assert_eq!(fletcher_words(&words), (u32::MAX, u32::MAX));
    }

    #[test]
    fn bytes_match_words_for_aligned_input() {
        let words = [0xDEADBEEFu32, 0x01020304, 0xFFFFFFFF];
        let mut bytes = Vec::new();
        for w in words {
            bytes.extend_from_slice(&w.to_le_bytes());
        }
        assert_eq!(fletcher_bytes(&bytes), fletcher_words(&words));
    }

    #[test]
    fn trailing_partial_word_zero_padded() {
        let a = fletcher_bytes(&[0xAA]);
        let b = fletcher_bytes(&[0xAA, 0, 0, 0]);
        assert_eq!(a, b);
    }

    #[test]
    fn order_sensitivity() {
        assert_ne!(fletcher_words(&[1, 2]), fletcher_words(&[2, 1]));
    }

    #[test]
    fn single_bit_sensitivity() {
        let base = fletcher64(&[0u8; 64]);
        for i in 0..64 {
            let mut buf = [0u8; 64];
            buf[i] = 1;
            assert_ne!(fletcher64(&buf), base, "byte {i}");
        }
    }
}
