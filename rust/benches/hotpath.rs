//! Wall-clock hot-path benchmarks (the §Perf targets in EXPERIMENTS.md):
//!
//!  * simulator append throughput per method class (the L3 hot loop),
//!  * record checksumming (requester-side integrity hot path),
//!  * recovery scan throughput (rust mirror; the XLA path is measured in
//!    `examples/crash_recovery.rs` since it needs artifacts),
//!  * wire envelope encode/decode,
//!  * crash-image reconstruction.

use rpmem::bench::run;
use rpmem::fabric::engine::Fabric;
use rpmem::fabric::timing::TimingModel;
use rpmem::integrity::fletcher64;
use rpmem::persist::config::{PDomain, RqwrbLoc, ServerConfig};
use rpmem::persist::exec::{exec_compound, exec_singleton, Update};
use rpmem::persist::method::{CompoundMethod, SingletonMethod};
use rpmem::persist::wire::{self, WireUpdate};
use rpmem::remotelog::log::{make_record, APP_WORDS, RECORD_BYTES};
use rpmem::remotelog::recovery::{RustScanner, Scanner};
use rpmem::server::memory::Layout;

fn fabric(cfg: ServerConfig) -> Fabric {
    let layout = Layout::new(1 << 22, 1 << 20, 64, 8192, cfg.rqwrb);
    Fabric::new(cfg, TimingModel::default(), layout, 7, false)
}

fn main() {
    println!("== L3 simulator hot path ==");
    {
        let mut f = fabric(ServerConfig::new(PDomain::Wsp, false, RqwrbLoc::Dram));
        let mut i = 0u64;
        run("sim/append one-sided WriteComp (WSP)", || {
            let u = Update::new(0x10000 + (i % 512) * 64, vec![1u8; 64]);
            exec_singleton(&mut f, SingletonMethod::WriteComp, &u, i as u32);
            i += 1;
        });
    }
    {
        let mut f = fabric(ServerConfig::new(PDomain::Mhp, false, RqwrbLoc::Dram));
        let mut i = 0u64;
        run("sim/append one-sided WriteFlush (MHP)", || {
            let u = Update::new(0x10000 + (i % 512) * 64, vec![1u8; 64]);
            exec_singleton(&mut f, SingletonMethod::WriteFlush, &u, i as u32);
            i += 1;
        });
    }
    {
        let mut f = fabric(ServerConfig::new(PDomain::Dmp, true, RqwrbLoc::Dram));
        let mut i = 0u64;
        run("sim/append two-sided SendCopyFlushAck (DMP)", || {
            let u = Update::new(0x10000 + (i % 512) * 64, vec![1u8; 64]);
            exec_singleton(&mut f, SingletonMethod::SendCopyFlushAck, &u, i as u32);
            i += 1;
        });
    }
    {
        let mut f = fabric(ServerConfig::new(PDomain::Dmp, false, RqwrbLoc::Dram));
        let mut i = 0u64;
        run("sim/append compound atomic pipeline (DMP)", || {
            let a = Update::new(0x10000 + (i % 512) * 64, vec![1u8; 64]);
            let b = Update::new(0x100, (i + 1).to_le_bytes().to_vec());
            exec_compound(
                &mut f,
                CompoundMethod::WriteFlushAtomicFlush,
                &a,
                &b,
                i as u32,
            );
            i += 1;
        });
    }

    println!("\n== integrity hot path ==");
    {
        let mut seq = 0u64;
        let app = [0xDEADBEEFu32; APP_WORDS];
        run("integrity/make_record (checksum 64B)", || {
            std::hint::black_box(make_record(seq, &app));
            seq += 1;
        });
    }
    {
        let buf = vec![0xA5u8; 4096];
        run("integrity/fletcher64 4KiB", || {
            std::hint::black_box(fletcher64(&buf));
        });
    }

    println!("\n== recovery scan (rust mirror) ==");
    {
        let n = 16384usize;
        let mut log = Vec::with_capacity(n * RECORD_BYTES);
        for s in 0..n {
            log.extend_from_slice(&make_record(s as u64, &[s as u32; APP_WORDS]));
        }
        let r = run("recovery/scan 16Ki records (1 MiB)", || {
            std::hint::black_box(RustScanner.scan(&log));
        });
        println!(
            "    -> {:.2} GiB/s scan bandwidth",
            (n * RECORD_BYTES) as f64 / r.median_ns_per_iter / 1.073_741_824
        );
    }

    println!("\n== wire envelope ==");
    {
        let ups = [
            WireUpdate { target: 0x1000, data: vec![1u8; 64] },
            WireUpdate { target: 0x100, data: vec![2u8; 8] },
        ];
        run("wire/encode compound message", || {
            std::hint::black_box(wire::encode(7, &ups));
        });
        let buf = wire::encode(7, &ups);
        run("wire/decode compound message", || {
            std::hint::black_box(wire::decode(&buf).unwrap());
        });
    }

    println!("\n== crash-image reconstruction ==");
    {
        let cfg = ServerConfig::new(PDomain::Dmp, false, RqwrbLoc::Dram);
        let layout = Layout::new(1 << 18, 1 << 16, 64, 512, cfg.rqwrb);
        let mut f = Fabric::new(cfg, TimingModel::default(), layout, 7, true);
        for i in 0..1000u64 {
            let u = Update::new(0x1000 + (i % 512) * 64, vec![1u8; 64]);
            exec_singleton(&mut f, SingletonMethod::WriteFlush, &u, i as u32);
        }
        let end = f.now();
        let mut t = 0u64;
        run("crash/image @1000 writes (256 KiB PM)", || {
            t = (t + end / 7) % end;
            std::hint::black_box(f.mem.crash_image(t, PDomain::Dmp));
        });
    }
}
