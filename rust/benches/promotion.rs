//! Live coordinator failover campaign: every (config, clients)
//! scenario runs a no-death baseline, then kills the acting
//! coordinator at the midpoint of the baseline makespan
//! (`persist::promotion` via `coordinator::scaling::run_promotion_grid`),
//! across ALL 16 grid configurations (12 taxonomy + 4 async-flush VPM
//! rows). The witness shard detects the death by reactor-lease expiry,
//! reads the durable decision/manifest/intent prefix over one-sided
//! ops, and promotes itself to acting coordinator, finishing every
//! in-flight group.
//!
//! Results are persisted as a JSON artifact (`RPMEM_PROMOTION_OUT`,
//! default `promotion_results.json`); the artifact is a pure function
//! of the knobs, so CI double-runs it and diffs the bytes. Four guards
//! are asserted:
//!
//! * **takeover beats offline recovery** — on EVERY row the measured
//!   death-to-resumption latency is strictly below the modeled offline
//!   merged-ring recovery (same lease wait and takeover train, read
//!   pass replaced by QP re-establishment + full-region bulk scan);
//! * **detection is exactly one lease TTL** — the coordinator
//!   heartbeats up to the instant it dies, so `detected_at - died_at`
//!   equals the TTL on every row;
//! * **the goodput dip is real but bounded** — every client still
//!   commits its full quota, goodput never collapses to zero, and
//!   retention against the no-death baseline is strictly below 1
//!   (dead air costs throughput) on every row;
//! * **the campaign is correct and can still fail** — a recording
//!   death run crash-sweeps clean at every instant, and a
//!   promotion-disabled control MUST trip the lock-leak / stranded-
//!   timer tripwires.
//!
//! Fast mode: `RPMEM_BENCH_FAST=1` (CI bench-smoke job).

use rpmem::coordinator::scaling::{
    promotion_grid_to_json, render_promotion_grid, run_promotion_grid,
    ScalingOpts,
};
use rpmem::fabric::timing::TimingModel;
use rpmem::persist::config::{PDomain, RqwrbLoc, ServerConfig};
use rpmem::persist::contention::ContentionOpts;
use rpmem::persist::promotion::{
    promotion_sweep, run_promotion, PromotionOpts,
};
use std::time::Instant;

fn main() {
    let txns: u64 = if rpmem::bench::fast() { 4 } else { 12 };
    let clients_list: &[usize] =
        if rpmem::bench::fast() { &[3] } else { &[3, 6] };
    let shards = 3usize;
    let lease = 50_000u64;
    let opts = ScalingOpts { capacity: 64, ..Default::default() };
    println!(
        "live coordinator failover, {txns} txns/client, clients \
         {clients_list:?}, {shards} shards, lease {lease} ns, 16 configs\n"
    );

    let t0 = Instant::now();
    let points = run_promotion_grid(clients_list, shards, txns, lease, &opts);
    let wall = t0.elapsed();
    let title = "live coordinator failover across the grid — witness \
                 takeover vs offline recovery";
    println!("{}", render_promotion_grid(title, &points));
    println!("  [harness: {:.2?} wall-clock]\n", wall);
    assert_eq!(points.len(), 16 * clients_list.len());

    // Guard 1: the headline — live takeover strictly beats the offline
    // recovery it replaces, on every row, and the win is structural
    // (the read pass is a small fraction of even the takeover window).
    for p in &points {
        let label = format!("{} clients={}", p.config.label(), p.clients);
        assert!(
            p.takeover_ns < p.offline_ns,
            "{label}: takeover {} ns must beat offline {} ns",
            p.takeover_ns,
            p.offline_ns
        );
        assert!(
            p.speedup() > 1.0,
            "{label}: speedup {:.2} must exceed 1",
            p.speedup()
        );
        // Guard 2: detection is exactly one lease TTL after the death.
        assert_eq!(
            p.detected_at,
            p.died_at + lease,
            "{label}: the lease must expire one TTL after the last beat"
        );
        // Guard 3: the dip is real but bounded.
        assert_eq!(
            p.committed,
            p.clients as u64 * txns,
            "{label}: every client must commit its full quota"
        );
        assert!(p.goodput_mtps > 0.0, "{label}: goodput collapsed");
        assert!(
            p.retention() < 1.0,
            "{label}: a death cannot be free: retention {:.4}",
            p.retention()
        );
        assert!(
            p.retention() > 0.0,
            "{label}: retention collapsed: {:.4}",
            p.retention()
        );
    }
    let mean_speedup = points.iter().map(|p| p.speedup()).sum::<f64>()
        / points.len() as f64;
    let mean_retention = points.iter().map(|p| p.retention()).sum::<f64>()
        / points.len() as f64;
    println!(
        "takeover wins everywhere: mean {mean_speedup:.1}x vs offline, \
         mean goodput retention {mean_retention:.3}\n"
    );

    // Guard 4a: correctness — a recording death run survives the full
    // crash sweep (uniform instants + every ack and every takeover
    // boundary ± 1 ns).
    let cfg = ServerConfig::new(PDomain::Mhp, false, RqwrbLoc::Dram);
    let rec = PromotionOpts {
        load: ContentionOpts {
            clients: 3,
            txns_per_client: 4,
            keys: 16,
            shards,
            capacity: 64,
            record: true,
            replicate: true,
            ..Default::default()
        },
        ..Default::default()
    };
    let probe = run_promotion(
        cfg,
        TimingModel::default(),
        &PromotionOpts { die_at: None, ..rec.clone() },
    );
    let deadly = PromotionOpts {
        die_at: Some(probe.result.span_ns / 2),
        ..rec.clone()
    };
    let run = run_promotion(cfg, TimingModel::default(), &deadly);
    assert_eq!(run.takeovers.len(), 1, "the death must promote the witness");
    let violations = promotion_sweep(&run, 120);
    assert!(
        violations.is_empty(),
        "promotion crash sweep found violations: {violations:?}"
    );
    println!(
        "crash sweep clean: {} commits, takeover in {} ns, every instant \
         prefix-consistent",
        run.result.committed,
        run.result.takeover_ns().unwrap()
    );

    // Guard 4b: the promotion-disabled control must leak — the
    // tripwires exist to catch exactly this bug class.
    let control = PromotionOpts { enabled: false, ..deadly };
    let bad = run_promotion(cfg, TimingModel::default(), &control);
    assert!(
        !bad.leaked_locks.is_empty() || bad.stranded_timer_refs > 0,
        "an undetected death must leak locks or strand timers"
    );
    let caught = promotion_sweep(&bad, 60);
    assert!(
        caught.iter().any(|v| v.contains("leaked lock")
            || v.contains("dead coordinator")),
        "disabled promotion must fail the sweep: {caught:?}"
    );
    println!(
        "negative control: promotion disabled -> {} violations (detected, \
         as required)\n",
        caught.len()
    );

    let out = std::env::var("RPMEM_PROMOTION_OUT")
        .unwrap_or_else(|_| "promotion_results.json".to_string());
    std::fs::write(&out, promotion_grid_to_json(&points).to_string_pretty())
        .expect("write promotion JSON artifact");
    println!("wrote {out} ({} points)", points.len());
}
