//! Figure 2 (a)/(b)/(c): REMOTELOG singleton-append latency across all
//! twelve server configurations × three primary operations, per
//! persistence domain. Regenerates the paper's series (simulated
//! virtual-time latency; the reproduction target is the *shape* — see
//! EXPERIMENTS.md) and reports the wall-clock cost of generating each
//! panel.

use rpmem::coordinator::sweep::{render_panel, run_figure_panel, SweepOpts};
use rpmem::persist::config::PDomain;
use rpmem::remotelog::client::AppendMode;
use std::time::Instant;

fn main() {
    let opts = SweepOpts {
        appends: rpmem::bench::scaled(50_000),
        ..Default::default()
    };
    println!(
        "REMOTELOG singleton appends, 64 B records, {} appends/bar\n",
        opts.appends
    );
    for (title, pd) in [
        ("Fig 2(a) — singleton updates, DMP", PDomain::Dmp),
        ("Fig 2(b) — singleton updates, MHP", PDomain::Mhp),
        ("Fig 2(c) — singleton updates, WSP", PDomain::Wsp),
    ] {
        let t0 = Instant::now();
        let results = run_figure_panel(pd, AppendMode::Singleton, &opts);
        let wall = t0.elapsed();
        println!("{}", render_panel(title, &results));
        let sim_appends = opts.appends * results.len() as u64;
        println!(
            "  [harness: {} simulated appends in {:.2?} — {:.2}M appends/s wall-clock]\n",
            sim_appends,
            wall,
            sim_appends as f64 / wall.as_secs_f64() / 1e6
        );
    }
}
