//! Zipfian hot-key contention campaign: concurrent read-modify-write
//! transactions race through the per-key lock table
//! (`persist::contention`) at rising skew θ, across ALL 16 grid
//! configurations (12 taxonomy + 4 async-flush VPM rows), with conflict
//! losers aborting and retrying as backed-off reactor timer events.
//! Every (config, clients) scenario is also run at θ=0 as the uniform
//! control, and each point reports the goodput retained against it.
//!
//! Results are persisted as a JSON artifact (`RPMEM_CONTENTION_OUT`,
//! default `contention_results.json`); the artifact is a pure function
//! of the knobs, so CI double-runs it and diffs the bytes. Four guards
//! are asserted:
//!
//! * **goodput degrades gracefully** — within every (config, clients)
//!   scenario goodput is non-increasing in θ (small slack for key-
//!   routing noise), never collapses to zero, and every client still
//!   commits its full quota; the grid-wide mean retention at the
//!   hottest θ is strictly below 1 (skew really taxes throughput);
//! * **contention really happened** — the hottest θ aborts strictly
//!   more than uniform does across the grid;
//! * **the campaign is correct** — a recording run is crash-swept at
//!   uniform instants plus every ack ± 1 ns: no lost update, no torn
//!   multi-key snapshot, no visible aborted state anywhere;
//! * **the harness can still fail** — a sabotaged lock table that
//!   admits every proposal MUST trip the lost-update check, and a θ=0
//!   max_group=1 run replays bit-identically through the plain grouped
//!   runner from its recorded flush batches.
//!
//! Fast mode: `RPMEM_BENCH_FAST=1` (CI bench-smoke job).

use rpmem::coordinator::scaling::{
    contention_grid_to_json, render_contention_grid, run_contention_grid,
    ScalingOpts,
};
use rpmem::fabric::timing::TimingModel;
use rpmem::kvstore::ShardedKv;
use rpmem::persist::config::{PDomain, RqwrbLoc, ServerConfig};
use rpmem::persist::contention::{
    contention_sweep, run_contention, ContentionOpts,
};
use rpmem::persist::groupcommit::GroupCommitOpts;
use std::time::Instant;

fn main() {
    let txns: u64 = if rpmem::bench::fast() { 8 } else { 96 };
    let clients_list: &[usize] =
        if rpmem::bench::fast() { &[4] } else { &[4, 8] };
    let thetas = [0.0, 0.6, 0.9, 0.99];
    let shards = 2usize;
    let opts = ScalingOpts::default();
    println!(
        "zipfian contention, {txns} txns/client, clients {clients_list:?}, \
         {shards} shards, theta {thetas:?}, 16 configs\n"
    );

    let t0 = Instant::now();
    let points =
        run_contention_grid(&thetas, clients_list, shards, txns, &opts);
    let wall = t0.elapsed();
    let title = "zipfian contention across the grid — goodput retained vs \
                 the uniform baseline";
    println!("{}", render_contention_grid(title, &points));
    println!("  [harness: {:.2?} wall-clock]\n", wall);
    assert_eq!(points.len(), 16 * clients_list.len() * thetas.len());

    // Guard 1: within every (config, clients) scenario — the grid emits
    // one θ-ordered chunk per scenario — goodput degrades monotonically
    // (5% slack absorbs key-routing noise at low θ, where different
    // draws shift shard load without contention), never to zero, with
    // every client still committing its quota.
    for chunk in points.chunks_exact(thetas.len()) {
        let label = format!(
            "{} clients={}",
            chunk[0].config.label(),
            chunk[0].clients
        );
        for p in chunk {
            assert_eq!(
                p.committed,
                p.clients as u64 * txns,
                "{label}: every client must commit its full quota"
            );
            assert!(
                p.goodput_mtps > 0.0,
                "{label} theta={}: goodput collapsed to zero",
                p.theta
            );
        }
        for w in chunk.windows(2) {
            assert!(
                w[1].goodput_mtps <= w[0].goodput_mtps * 1.05,
                "{label}: goodput rose with skew: theta {} -> {} went \
                 {:.4} -> {:.4} Mtps",
                w[0].theta,
                w[1].theta,
                w[0].goodput_mtps,
                w[1].goodput_mtps
            );
        }
        assert!(
            chunk[0].retention() > 0.999_999 && chunk[0].retention() < 1.000_001,
            "{label}: theta=0 must match its own uniform baseline"
        );
    }

    // Grid-wide: mean retention is non-increasing in θ and the hottest
    // θ lands strictly below 1 — the skew tax is real, not noise.
    let mean_retention: Vec<f64> = (0..thetas.len())
        .map(|i| {
            let scenarios = points.len() / thetas.len();
            points
                .chunks_exact(thetas.len())
                .map(|c| c[i].retention())
                .sum::<f64>()
                / scenarios as f64
        })
        .collect();
    for w in mean_retention.windows(2) {
        assert!(
            w[1] <= w[0] * 1.01,
            "mean retention rose with skew: {mean_retention:?}"
        );
    }
    assert!(
        mean_retention[thetas.len() - 1] < 1.0,
        "theta=0.99 must tax goodput somewhere: {mean_retention:?}"
    );

    // Guard 2: the hot tail really contends.
    let aborts_at = |i: usize| -> u64 {
        points.chunks_exact(thetas.len()).map(|c| c[i].aborts).sum()
    };
    assert!(
        aborts_at(thetas.len() - 1) > aborts_at(0),
        "theta=0.99 must abort more than uniform across the grid"
    );
    println!(
        "skew tax: mean retention {:.3} at theta=0.99, {} aborts (uniform: \
         {})\n",
        mean_retention[thetas.len() - 1],
        aborts_at(thetas.len() - 1),
        aborts_at(0)
    );

    // Guard 3: correctness under contention — a recording run survives
    // the full crash sweep (uniform instants + every ack ± 1 ns).
    let cfg = ServerConfig::new(PDomain::Mhp, false, RqwrbLoc::Dram);
    let rec = ContentionOpts {
        clients: 6,
        txns_per_client: 8,
        keys: 8,
        keys_per_txn: 2,
        theta: 0.9,
        shards,
        capacity: 64,
        record: true,
        ..Default::default()
    };
    let run = run_contention(cfg, TimingModel::default(), &rec);
    assert!(run.result.aborts > 0, "the hot recording run must conflict");
    let violations = contention_sweep(&run, 200);
    assert!(
        violations.is_empty(),
        "contention crash sweep found violations: {violations:?}"
    );
    println!(
        "crash sweep clean: {} commits, {} aborts, every instant \
         prefix-consistent",
        run.result.committed, run.result.aborts
    );

    // Guard 4a: the sabotaged lock table (admits every proposal) must
    // lose updates — the sweep exists to catch exactly this bug class.
    let broken = ContentionOpts {
        clients: 4,
        txns_per_client: 4,
        keys: 1,
        keys_per_txn: 1,
        theta: 0.0,
        capacity: 64,
        record: true,
        broken_locks: true,
        ..Default::default()
    };
    let bad = run_contention(cfg, TimingModel::default(), &broken);
    let caught = contention_sweep(&bad, 80);
    assert!(
        caught.iter().any(|v| v.contains("lost update")),
        "a broken lock table must fail the sweep: {caught:?}"
    );
    println!(
        "negative control: broken lock table -> {} violations (detected, \
         as required)",
        caught.len()
    );

    // Guard 4b: θ=0 with max_group=1 is a pure `put_txn_grouped` call
    // sequence — replaying the recorded flush batches on a fresh store
    // reproduces every ack, the makespan, and the final state bit for
    // bit (the existing grouped runner IS the contention engine's
    // substrate, unchanged).
    let unit = ContentionOpts {
        clients: 4,
        txns_per_client: 8,
        theta: 0.0,
        shards,
        capacity: 64,
        record: true,
        group: GroupCommitOpts { max_group: 1, ..Default::default() },
        ..Default::default()
    };
    let urun = run_contention(cfg, TimingModel::default(), &unit);
    let mut fresh = ShardedKv::new(
        cfg,
        TimingModel::default(),
        unit.capacity,
        unit.shards,
        unit.seed,
        unit.record,
    )
    .with_decision_replication(unit.replicate);
    let mut acks = Vec::new();
    for batch in &urun.flush_batches {
        acks.extend(fresh.put_txn_grouped(batch, &unit.group));
    }
    let want: Vec<u64> = urun.commits.iter().map(|c| c.acked_at).collect();
    assert_eq!(acks, want, "unit-group replay must reproduce every ack");
    assert_eq!(fresh.makespan(), urun.kv.makespan());
    assert_eq!(
        fresh.recover_all_at(fresh.makespan()),
        urun.snapshot_at(urun.kv.makespan())
    );
    println!("unit-group identity: replayed flush batches bit-identical\n");

    let out = std::env::var("RPMEM_CONTENTION_OUT")
        .unwrap_or_else(|_| "contention_results.json".to_string());
    std::fs::write(&out, contention_grid_to_json(&points).to_string_pretty())
        .expect("write contention JSON artifact");
    println!("wrote {out} ({} points)", points.len());
}
