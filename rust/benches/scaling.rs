//! Throughput scaling: M clients × window-W doorbell-batched pipelines
//! over an N-QP sharded fabric — the scaling table that sits alongside
//! the paper's latency figures (Fig 2).
//!
//! Sweeps clients ∈ {1,2,4,8,16} with one QP per client (the scaling
//! axis) for four representative method classes, plus a saturation axis
//! (16 clients crammed onto fewer QPs). Results are persisted as a JSON
//! artifact (`RPMEM_SCALING_OUT`, default `scaling_results.json`) and
//! the scaling axis is asserted monotone for the pipelinable one-sided
//! methods — a regression here means the sharded layer stopped scaling.
//!
//! Fast mode: `RPMEM_BENCH_FAST=1` (CI bench-smoke job).

use rpmem::bench::scaled;
use rpmem::coordinator::scaling::{
    render_scaling, run_saturation_axis, run_scaling_axis, scaling_to_json,
    ScalingOpts,
};
use rpmem::persist::config::{PDomain, RqwrbLoc, ServerConfig};
use rpmem::persist::method::Primary;
use rpmem::remotelog::client::AppendMode;
use std::time::Instant;

fn main() {
    let opts = ScalingOpts {
        appends_per_client: scaled(20_000),
        ..Default::default()
    };
    let clients = [1usize, 2, 4, 8, 16];
    println!(
        "multi-client scaling, {} appends/client, window {}, batch {}\n",
        opts.appends_per_client, opts.window, opts.batch
    );

    let scenarios: [(&str, ServerConfig, AppendMode, Primary, bool); 4] = [
        (
            "WSP one-sided Write;Comp (singleton)",
            ServerConfig::new(PDomain::Wsp, false, RqwrbLoc::Dram),
            AppendMode::Singleton,
            Primary::Write,
            true,
        ),
        (
            "MHP one-sided Write;Flush (singleton)",
            ServerConfig::new(PDomain::Mhp, false, RqwrbLoc::Dram),
            AppendMode::Singleton,
            Primary::Write,
            true,
        ),
        (
            "DMP ¬DDIO atomic pipeline (compound)",
            ServerConfig::new(PDomain::Dmp, false, RqwrbLoc::Dram),
            AppendMode::Compound,
            Primary::Write,
            true,
        ),
        (
            "DMP+DDIO two-sided Send (responder-CPU-bound)",
            ServerConfig::new(PDomain::Dmp, true, RqwrbLoc::Dram),
            AppendMode::Singleton,
            Primary::Send,
            false,
        ),
    ];

    let mut all = Vec::new();
    for (title, cfg, mode, primary, assert_monotone) in scenarios {
        let t0 = Instant::now();
        let points = run_scaling_axis(cfg, mode, primary, &clients, &opts);
        let wall = t0.elapsed();
        let label =
            format!("{title}  [{} | {}]", points[0].method_name, cfg.label());
        println!("{}", render_scaling(&label, &points));
        println!("  [harness: {:.2?} wall-clock]\n", wall);
        if assert_monotone {
            for w in points.windows(2) {
                assert!(
                    w[1].throughput_mops >= w[0].throughput_mops * 0.999,
                    "scaling regression: {} clients {:.2} Mops -> {} \
                     clients {:.2} Mops",
                    w[0].clients,
                    w[0].throughput_mops,
                    w[1].clients,
                    w[1].throughput_mops
                );
            }
        }
        all.extend(points);
    }

    println!("saturation: 16 clients on fewer QPs (MHP Write;Flush)\n");
    let sat_cfg = ServerConfig::new(PDomain::Mhp, false, RqwrbLoc::Dram);
    for shards in [1usize, 2, 4, 8, 16] {
        let points = run_saturation_axis(
            sat_cfg,
            AppendMode::Singleton,
            Primary::Write,
            shards,
            &[16],
            &opts,
        );
        println!(
            "  shards={:<3} {:>9.2} Mops  (mean lat {:>8.2} us)",
            shards,
            points[0].throughput_mops,
            points[0].mean_latency_ns / 1e3
        );
        all.extend(points);
    }
    println!();

    let out = std::env::var("RPMEM_SCALING_OUT")
        .unwrap_or_else(|_| "scaling_results.json".to_string());
    std::fs::write(&out, scaling_to_json(&all).to_string_pretty())
        .expect("write scaling JSON artifact");
    println!("wrote {out} ({} points)", all.len());
}
