//! Async-flush (VPM) amortization: the flush-command round trip is the
//! persistence point for every virtio-pmem-style config, and its fixed
//! host-fsync cost (`vpmem_flush_base_ns`) dominates the write-back
//! cost — so coalescing one flush command per doorbell train (and one
//! per commit group) is the whole performance story of the device
//! class.
//!
//! Two axes, both guarded by strict monotonicity asserts:
//!
//! * **singleton train coalescing** — `post_singleton_batch` posts N
//!   writes plus ONE trailing flush command; virtual ns/append must be
//!   strictly decreasing in the train length for every VPM config and
//!   every flush-command recipe;
//! * **group commit** — `run_group_grid_over` on the VPM rows: the
//!   amortized per-transaction decision cost must strictly improve
//!   from group size 1 → 4 → max (the group shares one host fsync
//!   round trip), and grouping never loses throughput.
//!
//! Results are persisted as a JSON artifact (`RPMEM_ASYNCFLUSH_OUT`,
//! default `asyncflush_results.json`). Fast mode: `RPMEM_BENCH_FAST=1`
//! (CI bench-smoke job; the artifact stays byte-deterministic because
//! every reported number is virtual-time).

use rpmem::bench::scaled;
use rpmem::coordinator::scaling::{
    group_grid_to_json, render_group_grid, run_group_grid_over, ScalingOpts,
};
use rpmem::fabric::engine::Fabric;
use rpmem::fabric::timing::TimingModel;
use rpmem::persist::config::ServerConfig;
use rpmem::persist::exec::{exec_singleton_batch, Update};
use rpmem::persist::method::Primary;
use rpmem::persist::planner::plan_singleton;
use rpmem::server::memory::Layout;
use rpmem::util::json::Json;
use std::time::Instant;

/// Virtual ns/append for one coalesced train of `batch` updates.
fn train_ns_per_append(
    cfg: ServerConfig,
    primary: Primary,
    batch: usize,
    trains: u64,
) -> f64 {
    let layout = Layout::new(1 << 20, 1 << 18, 64, 8192, cfg.rqwrb);
    let mut fab = Fabric::new(cfg, TimingModel::default(), layout, 7, false);
    let method = plan_singleton(&cfg, primary);
    let mut total = 0u64;
    for t in 0..trains {
        let updates: Vec<Update> = (0..batch)
            .map(|i| {
                Update::new(0x10000 + (i as u64 % 512) * 64, vec![1u8; 64])
            })
            .collect();
        let out = exec_singleton_batch(&mut fab, method, &updates, t as u32);
        total += out.latency();
    }
    total as f64 / (trains * batch as u64) as f64
}

fn main() {
    let trains = scaled(200);
    let txns = scaled(2000);
    let batches = [1usize, 2, 4, 8, 16, 32];
    let vpm = ServerConfig::async_flush_rows();
    println!(
        "async-flush amortization, {trains} trains x batches {batches:?}, \
         {txns} txns/client group grid, {} VPM configs\n",
        vpm.len()
    );

    // Axis 1: one flush command per doorbell train.
    let mut coalescing = Vec::new();
    println!(
        "{:<22} {:<26} {:>6} {:>14}",
        "config", "method", "batch", "ns/append"
    );
    println!("{}", "-".repeat(72));
    for &cfg in &vpm {
        for primary in Primary::ALL {
            let method = plan_singleton(&cfg, primary);
            let mut prev = f64::INFINITY;
            for &b in &batches {
                let ns = train_ns_per_append(cfg, primary, b, trains);
                println!(
                    "{:<22} {:<26} {:>6} {:>14.1}",
                    cfg.label(),
                    method.name(),
                    b,
                    ns
                );
                assert!(
                    ns < prev,
                    "{} {}: flush coalescing must strictly amortize \
                     batch {b}: {ns:.1} !< {prev:.1}",
                    cfg.label(),
                    method.name()
                );
                prev = ns;
                let mut j = Json::obj();
                j.set("config", cfg.label().into())
                    .set("method", method.name().into())
                    .set("batch", (b as u64).into())
                    .set("ns_per_append", ns.into());
                coalescing.push(j);
            }
        }
    }

    // Axis 2: one flush command per commit group.
    let groups = [1usize, 4, 16];
    let clients = [1usize, 2];
    let shards = 4usize;
    let opts = ScalingOpts { capacity: txns.max(16), ..Default::default() };
    let t0 = Instant::now();
    let points = run_group_grid_over(
        &vpm,
        Primary::Write,
        &groups,
        &clients,
        shards,
        txns,
        &opts,
    );
    let wall = t0.elapsed();
    let title = "group commit on the async-flush rows — one host fsync \
                 round trip per group";
    println!("\n{}", render_group_grid(title, &points));
    println!("  [harness: {:.2?} wall-clock]\n", wall);

    for scenario in points.chunks(groups.len()) {
        let label = format!(
            "{} x {} clients",
            scenario[0].config.label(),
            scenario[0].clients
        );
        for pair in scenario.windows(2) {
            assert!(
                pair[1].decision_ns_per_txn < pair[0].decision_ns_per_txn,
                "{label}: flush amortization must strictly improve \
                 {} -> {}: {:.1} !< {:.1}",
                pair[0].group,
                pair[1].group,
                pair[1].decision_ns_per_txn,
                pair[0].decision_ns_per_txn
            );
        }
        for p in scenario {
            assert!(
                p.grouped_mtps >= p.ungrouped_mtps * 0.999,
                "{label}: group {} lost throughput: {:.3} vs {:.3}",
                p.group,
                p.grouped_mtps,
                p.ungrouped_mtps
            );
        }
    }

    let mut artifact = Json::obj();
    artifact
        .set("singleton_coalescing", Json::Arr(coalescing))
        .set("group_commit", group_grid_to_json(&points));
    let out = std::env::var("RPMEM_ASYNCFLUSH_OUT")
        .unwrap_or_else(|_| "asyncflush_results.json".to_string());
    std::fs::write(&out, artifact.to_string_pretty())
        .expect("write asyncflush JSON artifact");
    println!("wrote {out} ({} grid points)", points.len());
}
