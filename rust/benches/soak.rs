//! Hostile-network soak campaign: grouped 2PC with decision replication
//! under a seeded drop/jitter/partition/churn fault schedule
//! (`remotelog::soak`), across ALL 12 taxonomy configurations × 4
//! seeds, the retry engine re-posting lost trains. Every run is
//! crash-swept for the invariants — acked ⇒ recovered, committed
//! prefixes only on group boundaries — at uniform instants plus every
//! ack boundary.
//!
//! Results are persisted as a JSON artifact (`RPMEM_SOAK_OUT`, default
//! `soak_results.json`); the artifact is a pure function of the seeds,
//! so CI double-runs it and diffs the bytes. Three guards are asserted:
//!
//! * **the campaign is clean** — any violated run panics with the
//!   shrunk minimal fault schedule as a replayable `rpmem soak` line;
//! * **the faults really fired** — drops, retries, and the churn event
//!   are all non-zero somewhere in the grid (a soak that soaked
//!   nothing proves nothing);
//! * **the harness can still fail** — the same schedule with a
//!   sabotaged retry engine (acks fabricated over dropped trains, no
//!   re-post) MUST report violations, and a zero-fault max_group=1
//!   soak must replay the plain 2PC pipeline bit for bit.
//!
//! Fast mode: `RPMEM_BENCH_FAST=1` (CI bench-smoke job).

use rpmem::coordinator::scaling::{
    render_soak_grid, run_soak_grid, soak_grid_to_json,
};
use rpmem::fabric::timing::TimingModel;
use rpmem::persist::config::{PDomain, RqwrbLoc, ServerConfig};
use rpmem::persist::groupcommit::GroupCommitOpts;
use rpmem::persist::method::Primary;
use rpmem::remotelog::pipeline::{run_txn_multi_shard, TxnRunOpts};
use rpmem::remotelog::recovery::RustScanner;
use rpmem::remotelog::soak::{
    replay_line, run_soak_case, run_txn_soak, shrink_soak_failure, FaultPlan,
    SoakOpts, SoakStats,
};
use std::time::Instant;

fn main() {
    // Fast mode still needs >= 3 group-commit waves so the partition
    // (wave 1) and the churn event (wave 2) actually land.
    let txns: u64 = if rpmem::bench::fast() { 12 } else { 240 };
    let uniform_points: u64 = if rpmem::bench::fast() { 20 } else { 60 };
    let seeds = [1u64, 2, 3, 4];
    let base = SoakOpts {
        clients: 2,
        shards: 3,
        txns_per_client: txns,
        capacity: txns.max(32),
        replicate: true,
        group: GroupCommitOpts { max_group: 4, ..Default::default() },
        plan: FaultPlan {
            drop_per_mille: 20,
            jitter_ns: 200,
            duplicate_per_mille: 10,
            partition: Some((1, 60_000)),
            churn: Some((2, 60_000)),
        },
        ..Default::default()
    };
    println!(
        "hostile-network soak, {txns} txns/client, {} shards, 12 configs x \
         {} seeds (drop 20‰, jitter 200ns, dup 10‰, partition + churn)\n",
        base.shards,
        seeds.len()
    );

    let timing = TimingModel::default();
    let t0 = Instant::now();
    let points =
        run_soak_grid(Primary::Write, &seeds, &base, uniform_points, &timing);
    let wall = t0.elapsed();
    let title = "hostile-network soak across the taxonomy — 2PC invariants \
                 under drop/jitter/partition/churn";
    println!("{}", render_soak_grid(title, &points));
    println!("  [harness: {:.2?} wall-clock]\n", wall);

    // Guard 1: every run clean — shrink any failure to a minimal
    // replayable repro before dying.
    let table = ServerConfig::table1();
    for p in &points {
        if !p.clean {
            let ci = table
                .iter()
                .position(|c| c.label() == p.config.label())
                .expect("point config is a taxonomy row");
            let failing = SoakOpts { seed: p.seed, ..base };
            let minimal = shrink_soak_failure(
                p.config,
                &timing,
                Primary::Write,
                &failing,
                uniform_points,
                &RustScanner,
            );
            panic!(
                "{} seed {}: {} violations; minimal repro: {}",
                p.config.label(),
                p.seed,
                p.violations,
                replay_line(ci, &minimal)
            );
        }
    }

    // Guard 2: the soak actually soaked.
    let drops: u64 = points.iter().map(|p| p.dropped_ops).sum();
    let retries: u64 = points.iter().map(|p| p.retries).sum();
    assert!(drops > 0, "no train was ever dropped");
    assert!(retries > 0, "the retry engine never had to work");
    for p in &points {
        assert_eq!(
            p.churn_events,
            1,
            "{} seed {}: the churn event never landed",
            p.config.label(),
            p.seed
        );
        assert_eq!(
            p.txns + p.aborted_txns,
            txns * 2,
            "{} seed {}: acked + aborted must cover the stream",
            p.config.label(),
            p.seed
        );
    }

    // Guard 3a: a sabotaged retry engine (fabricated acks, no re-post)
    // must make the campaign fail — the harness can detect the bug
    // class it exists for.
    let broken = SoakOpts {
        clients: 2,
        shards: 2,
        txns_per_client: 8,
        capacity: 16,
        seed: 5,
        group: GroupCommitOpts { max_group: 4, ..Default::default() },
        plan: FaultPlan { drop_per_mille: 400, ..FaultPlan::none() },
        broken_retry: true,
        ..Default::default()
    };
    let cfg = ServerConfig::new(PDomain::Mhp, false, RqwrbLoc::Dram);
    let (_, stats, report) = run_soak_case(
        cfg,
        TimingModel::deterministic(),
        Primary::Write,
        &broken,
        30,
        &RustScanner,
    );
    assert!(stats.dropped_ops > 0);
    assert!(
        !report.clean(),
        "a broken retry engine must fail the campaign"
    );
    println!(
        "negative control: broken retry engine over {} drops -> {} \
         durability violations (detected, as required)",
        stats.dropped_ops, report.crash.durability_violations
    );

    // Guard 3b: a zero-fault max_group=1 soak IS the plain 2PC
    // pipeline, bit for bit.
    let benign = SoakOpts {
        clients: 2,
        shards: 2,
        txns_per_client: 8,
        capacity: 16,
        seed: 7,
        group: GroupCommitOpts { max_group: 1, ..Default::default() },
        ..Default::default()
    };
    let (_, soak, stats) = run_txn_soak(
        cfg,
        TimingModel::deterministic(),
        Primary::Write,
        &benign,
    );
    let (_, plain) = run_txn_multi_shard(
        cfg,
        TimingModel::deterministic(),
        Primary::Write,
        &TxnRunOpts {
            clients: 2,
            shards: 2,
            txns_per_client: 8,
            capacity: 16,
            seed: 7,
            record: true,
            atomic: true,
            replicate: false,
        },
    );
    assert_eq!(soak.span_ns, plain.span_ns);
    assert_eq!(soak.mean_latency_ns, plain.mean_latency_ns);
    assert_eq!(soak.decision_ns_total, plain.decision_ns_total);
    assert_eq!(stats, SoakStats::default(), "benign plan must be free");
    println!("zero-fault identity: soak(group=1, no faults) == plain 2PC\n");

    let out = std::env::var("RPMEM_SOAK_OUT")
        .unwrap_or_else(|_| "soak_results.json".to_string());
    std::fs::write(&out, soak_grid_to_json(&points).to_string_pretty())
        .expect("write soak JSON artifact");
    println!("wrote {out} ({} points)", points.len());
}
