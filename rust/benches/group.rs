//! Group-commit amortization: concurrent transactions' decision records
//! released as shared doorbell trains with one persistence point per
//! group (`persist::groupcommit`), vs the per-transaction 2PC baseline,
//! across group size × clients × ALL 12 taxonomy configurations.
//!
//! Results are persisted as a JSON artifact (`RPMEM_GROUP_OUT`, default
//! `group_results.json`). Three invariants are asserted:
//!
//! * **group size 1 is the baseline, exactly** — the degenerate
//!   schedule replays the ungrouped protocol op for op, so its
//!   throughput and decision cost must equal `run_txn_multi_shard`'s
//!   bit for bit;
//! * **the perf guard** — amortized per-transaction decision cost is
//!   *strictly decreasing* from group size 1 → 4 → max for every
//!   (config, clients) scenario (the group-commit analogue of the
//!   scaling bench's monotonicity assert: a regression here means the
//!   shared persistence point stopped amortizing);
//! * grouping never loses throughput against the per-transaction
//!   baseline.
//!
//! A small recording run additionally sweeps crashes and checks the
//! committed prefix only ever lands on group boundaries, so the bench
//! can never report an amortization whose recovery story is broken.
//!
//! Fast mode: `RPMEM_BENCH_FAST=1` (CI bench-smoke job).

use rpmem::bench::scaled;
use rpmem::coordinator::scaling::{
    group_grid_to_json, render_group_grid, run_group_grid, ScalingOpts,
};
use rpmem::fabric::timing::TimingModel;
use rpmem::persist::config::{PDomain, RqwrbLoc, ServerConfig};
use rpmem::persist::groupcommit::GroupCommitOpts;
use rpmem::persist::method::Primary;
use rpmem::remotelog::pipeline::{
    assert_group_boundaries, run_txn_grouped, txn_crash_sweep, GroupRunOpts,
};
use rpmem::remotelog::recovery::RustScanner;
use std::time::Instant;

fn main() {
    let txns = scaled(2000);
    let groups = [1usize, 4, 16];
    let clients = [1usize, 2];
    let shards = 4usize;
    let opts = ScalingOpts { capacity: txns.max(16), ..Default::default() };
    println!(
        "group commit, {txns} txns/client, {shards} shards, groups \
         {groups:?} x clients {clients:?} x 12 configs\n"
    );

    let t0 = Instant::now();
    let points =
        run_group_grid(Primary::Write, &groups, &clients, shards, txns, &opts);
    let wall = t0.elapsed();
    let title = "group commit across the taxonomy — shared vs per-txn \
                 decision trains";
    println!("{}", render_group_grid(title, &points));
    println!("  [harness: {:.2?} wall-clock]\n", wall);

    // Scenario = (config, clients); group sizes vary fastest.
    for scenario in points.chunks(groups.len()) {
        let label = format!(
            "{} x {} clients",
            scenario[0].config.label(),
            scenario[0].clients
        );
        let base = &scenario[0];
        assert_eq!(base.group, 1);
        assert_eq!(
            base.grouped_mtps,
            base.ungrouped_mtps,
            "{label}: group size 1 must BE the ungrouped protocol"
        );
        assert_eq!(
            base.decision_ns_per_txn,
            base.ungrouped_decision_ns_per_txn,
            "{label}: group size 1 decision cost must match the baseline"
        );
        for pair in scenario.windows(2) {
            assert!(
                pair[1].decision_ns_per_txn < pair[0].decision_ns_per_txn,
                "{label}: decision cost must strictly amortize \
                 {} -> {}: {:.1} !< {:.1}",
                pair[0].group,
                pair[1].group,
                pair[1].decision_ns_per_txn,
                pair[0].decision_ns_per_txn
            );
        }
        for p in scenario {
            assert!(
                p.grouped_mtps >= p.ungrouped_mtps * 0.999,
                "{label}: group {} lost throughput: {:.3} vs {:.3}",
                p.group,
                p.grouped_mtps,
                p.ungrouped_mtps
            );
        }
    }

    // Correctness smoke: the amortization we just measured must come
    // with whole-group crash atomicity.
    let gopts = GroupRunOpts {
        clients: 2,
        shards: 2,
        txns_per_client: 8,
        capacity: 16,
        seed: 31,
        record: true,
        replicate: false,
        group: GroupCommitOpts {
            max_group: 4,
            max_hold_ns: 1_000_000,
            idle_close: true,
        },
    };
    let (run, res) = run_txn_grouped(
        ServerConfig::new(PDomain::Mhp, false, RqwrbLoc::Dram),
        TimingModel::default(),
        Primary::Write,
        &gopts,
    );
    let rep = txn_crash_sweep(&run, 40, 7, &RustScanner);
    assert!(rep.clean(), "group-commit crash sweep: {rep:?}");
    let end = run.fabric.makespan();
    let instants: Vec<u64> = (0..=100).map(|i| end * i / 100).collect();
    assert_group_boundaries(&run, &res, &instants);
    println!(
        "group sweep clean over {} crash points; prefixes on group \
         boundaries",
        rep.crash_points
    );

    let out = std::env::var("RPMEM_GROUP_OUT")
        .unwrap_or_else(|_| "group_results.json".to_string());
    std::fs::write(&out, group_grid_to_json(&points).to_string_pretty())
        .expect("write group JSON artifact");
    println!("wrote {out} ({} points)", points.len());
}
