//! Figure 2 (d)/(e)/(f): REMOTELOG compound-append latency (record +
//! strictly-ordered tail pointer) across all twelve server
//! configurations × three primaries, per persistence domain.

use rpmem::coordinator::sweep::{render_panel, run_figure_panel, SweepOpts};
use rpmem::persist::config::PDomain;
use rpmem::remotelog::client::AppendMode;
use std::time::Instant;

fn main() {
    let opts = SweepOpts {
        appends: rpmem::bench::scaled(50_000),
        ..Default::default()
    };
    println!(
        "REMOTELOG compound appends (64 B record + 8 B tail pointer), {} appends/bar\n",
        opts.appends
    );
    for (title, pd) in [
        ("Fig 2(d) — compound updates, DMP", PDomain::Dmp),
        ("Fig 2(e) — compound updates, MHP", PDomain::Mhp),
        ("Fig 2(f) — compound updates, WSP", PDomain::Wsp),
    ] {
        let t0 = Instant::now();
        let results = run_figure_panel(pd, AppendMode::Compound, &opts);
        let wall = t0.elapsed();
        println!("{}", render_panel(title, &results));
        let sim_appends = opts.appends * results.len() as u64;
        println!(
            "  [harness: {} simulated appends in {:.2?} — {:.2}M appends/s wall-clock]\n",
            sim_appends,
            wall,
            sim_appends as f64 / wall.as_secs_f64() / 1e6
        );
    }
}
