//! Ablation benches for the design choices DESIGN.md calls out:
//!
//!  A. Native IBTA FLUSH vs RDMA READ emulation (§3.4).
//!  B. Compound ordering mechanism: WRITE_atomic pipeline vs the §4.2
//!     READ-pipeline performance *estimate* vs waiting for the first
//!     FLUSH completion (today's only correct option).
//!  C. IB/RoCE vs iWARP completion semantics under WSP (§3.2).
//!  D. RQ ring size back-pressure: server recycle rate vs client SEND
//!     rate (§4.3 "resource availability timeouts ... performance
//!     jitter").
//!  E. Record size sweep: where SEND message passing overtakes one-sided
//!     WRITE+FLUSH (copy cost vs round trips, §5).

use rpmem::fabric::timing::TimingModel;
use rpmem::persist::config::{
    Extensions, PDomain, RqwrbLoc, ServerConfig, Transport,
};
use rpmem::persist::exec::{exec_compound, exec_singleton, Update};
use rpmem::persist::method::{CompoundMethod, Primary, SingletonMethod};
use rpmem::remotelog::client::{AppendMode, MethodChoice, RemoteLog};
use rpmem::server::memory::Layout;
use rpmem::fabric::engine::Fabric;

fn iters() -> u64 {
    rpmem::bench::scaled(30_000)
}

fn mean_singleton(cfg: ServerConfig, m: SingletonMethod, len: usize) -> f64 {
    let n = iters();
    let layout = Layout::new(1 << 22, 1 << 20, 64, 8192, cfg.rqwrb);
    let mut f = Fabric::new(cfg, TimingModel::default(), layout, 7, false);
    let mut sum = 0u64;
    for i in 0..n {
        let u = Update::new(0x10000 + (i % 512) * 4096, vec![1u8; len]);
        sum += exec_singleton(&mut f, m, &u, i as u32).latency();
    }
    sum as f64 / n as f64
}

fn mean_compound(cfg: ServerConfig, m: CompoundMethod) -> f64 {
    let n = iters();
    let layout = Layout::new(1 << 22, 1 << 20, 64, 8192, cfg.rqwrb);
    let mut f = Fabric::new(cfg, TimingModel::default(), layout, 7, false);
    let mut sum = 0u64;
    for i in 0..n {
        let a = Update::new(0x10000 + (i % 512) * 64, vec![1u8; 64]);
        let b = Update::new(0x100, (i + 1).to_le_bytes().to_vec());
        sum += exec_compound(&mut f, m, &a, &b, i as u32).latency();
    }
    sum as f64 / n as f64
}

fn main() {
    println!("=== Ablation A: native FLUSH vs READ emulation ===");
    let base = ServerConfig::new(PDomain::Mhp, false, RqwrbLoc::Dram);
    let native = mean_singleton(base, SingletonMethod::WriteFlush, 64);
    let emu = mean_singleton(
        base.with_extensions(Extensions::Emulated),
        SingletonMethod::WriteFlush,
        64,
    );
    println!("  WRITE;FLUSH  native IBTA : {:8.2} us", native / 1e3);
    println!(
        "  WRITE;READ   emulated    : {:8.2} us  (+{:.0}%)\n",
        emu / 1e3,
        (emu - native) / native * 100.0
    );

    println!("=== Ablation B: compound ordering mechanism (DMP+¬DDIO) ===");
    let cfg = ServerConfig::new(PDomain::Dmp, false, RqwrbLoc::Dram);
    let atomic = mean_compound(cfg, CompoundMethod::WriteFlushAtomicFlush);
    let est = mean_compound(
        cfg.with_extensions(Extensions::Emulated),
        CompoundMethod::WriteFlushAtomicFlush, // §4.2 READ-pipeline estimate
    );
    let wait = mean_compound(cfg, CompoundMethod::WriteFlushWaitWriteFlush);
    println!("  WRITE_atomic pipeline (IBTA)      : {:8.2} us", atomic / 1e3);
    println!("  READ-pipeline estimate (§4.2)     : {:8.2} us", est / 1e3);
    println!(
        "  wait-for-FLUSH (correct today)    : {:8.2} us  ({:.1}x the atomic pipeline)\n",
        wait / 1e3,
        wait / atomic
    );

    println!("=== Ablation C: WSP under IB/RoCE vs iWARP ===");
    let wsp = ServerConfig::new(PDomain::Wsp, false, RqwrbLoc::Dram);
    let ib = mean_singleton(wsp, SingletonMethod::WriteComp, 64);
    // iWARP WSP must fall back to the MHP method (completion-only is
    // unsound — §3.2); measure what the planner would actually run.
    let iwarp_cfg = wsp.with_transport(Transport::Iwarp);
    let iw = mean_singleton(iwarp_cfg, SingletonMethod::WriteFlush, 64);
    println!("  IB/RoCE  WRITE;Comp               : {:8.2} us", ib / 1e3);
    println!(
        "  iWARP    WRITE;FLUSH (required)   : {:8.2} us  (+{:.0}%)\n",
        iw / 1e3,
        (iw - ib) / ib * 100.0
    );

    println!("=== Ablation D: RQ ring size back-pressure (SEND rate, slow server) ===");
    // A server that recycles receive buffers slowly (heavy stalls) makes
    // small rings throttle the client — the §4.3 jitter effect.
    let slow_cpu = TimingModel {
        cpu_stall_ns: 40_000,
        cpu_stall_period: 10,
        ..Default::default()
    };
    for ring in [2usize, 4, 8, 64] {
        let cfg = ServerConfig::new(PDomain::Mhp, false, RqwrbLoc::Pm);
        let layout = Layout::new(1 << 22, 1 << 20, ring, 8192, RqwrbLoc::Pm);
        let mut f = Fabric::new(cfg, slow_cpu.clone(), layout, 7, false);
        let mut rl_lat = rpmem::util::stats::Histogram::new();
        for i in 0..iters() / 3 {
            let u = Update::new(0x10000 + (i % 512) * 4096, vec![1u8; 64]);
            rl_lat.record(
                exec_singleton(&mut f, SingletonMethod::SendFlush, &u, i as u32)
                    .latency(),
            );
        }
        println!(
            "  ring={:<3} mean {:7.2} us   p99 {:7.2} us   max {:7.2} us",
            ring,
            rl_lat.summary().mean() / 1e3,
            rl_lat.quantile(0.99) as f64 / 1e3,
            rl_lat.summary().max() as f64 / 1e3
        );
    }
    println!();

    println!("=== Ablation E: record size — one-sided WRITE vs SEND msg passing (DMP+¬DDIO) ===");
    let cfg = ServerConfig::new(PDomain::Dmp, false, RqwrbLoc::Dram);
    for size in [64usize, 256, 1024, 4096] {
        let w = mean_singleton(cfg, SingletonMethod::WriteFlush, size);
        let s = mean_singleton(cfg, SingletonMethod::SendCopyFlushAck, size);
        println!(
            "  {:>5} B   WRITE;FLUSH {:8.2} us   SEND/copy/ack {:8.2} us   ({})",
            size,
            w / 1e3,
            s / 1e3,
            if w < s { "one-sided wins" } else { "msg passing wins" }
        );
    }

    println!("\n=== Ablation F: jitter sensitivity of append latency ===");
    for jit in [0u64, 200, 400, 800] {
        let timing = TimingModel { persist_jitter_ns: jit, ..Default::default() };
        let mut rl = RemoteLog::new(
            ServerConfig::new(PDomain::Mhp, false, RqwrbLoc::Dram),
            timing,
            AppendMode::Singleton,
            MethodChoice::Planned(Primary::Write),
            4096,
            7,
            false,
        );
        rl.run(iters() / 3);
        println!(
            "  placement jitter {:>4} ns: mean {:7.2} us  p99 {:7.2} us",
            jit,
            rl.latencies.summary().mean() / 1e3,
            rl.latencies.quantile(0.99) as f64 / 1e3
        );
    }
}
