//! Throughput extension: windowed (pipelined) REMOTELOG appends — the
//! dimension the paper's latency-only evaluation leaves open. Sweeps the
//! pipeline window per configuration class and reports sustained
//! simulated throughput + the latency cost of queueing.

use rpmem::fabric::timing::TimingModel;
use rpmem::persist::config::{PDomain, RqwrbLoc, ServerConfig};
use rpmem::persist::method::Primary;
use rpmem::remotelog::client::{AppendMode, MethodChoice, RemoteLog};
use rpmem::remotelog::pipeline::run_pipelined;

fn sweep(name: &str, cfg: ServerConfig, mode: AppendMode, primary: Primary) {
    println!("{name}  [{}]", cfg.label());
    println!(
        "  {:>7} {:>16} {:>14} {:>12}",
        "window", "throughput", "mean lat", "p99 lat"
    );
    for window in [1usize, 2, 4, 8, 16, 32, 64] {
        let mut rl = RemoteLog::new(
            cfg,
            TimingModel::default(),
            mode,
            MethodChoice::Planned(primary),
            8192,
            7,
            false,
        );
        let res = run_pipelined(&mut rl, rpmem::bench::scaled(30_000), window);
        println!(
            "  {:>7} {:>12.2} Mops {:>11.2} us {:>9.2} us",
            res.window,
            res.throughput_mops(),
            res.mean_latency_ns / 1e3,
            res.p99_latency_ns as f64 / 1e3,
        );
    }
    println!();
}

fn main() {
    println!("REMOTELOG pipelined append throughput (simulated time)\n");
    sweep(
        "singleton WRITE;Comp (WSP)",
        ServerConfig::new(PDomain::Wsp, false, RqwrbLoc::Dram),
        AppendMode::Singleton,
        Primary::Write,
    );
    sweep(
        "singleton WRITE;FLUSH (MHP)",
        ServerConfig::new(PDomain::Mhp, false, RqwrbLoc::Dram),
        AppendMode::Singleton,
        Primary::Write,
    );
    sweep(
        "singleton SEND one-sided (MHP, PM RQWRB — bounded by RQ recycling)",
        ServerConfig::new(PDomain::Mhp, false, RqwrbLoc::Pm),
        AppendMode::Singleton,
        Primary::Send,
    );
    sweep(
        "compound WRITE_atomic pipeline (DMP+¬DDIO)",
        ServerConfig::new(PDomain::Dmp, false, RqwrbLoc::Dram),
        AppendMode::Compound,
        Primary::Write,
    );
    sweep(
        "compound two-sided msg passing (DMP+DDIO — not pipelinable, window ignored)",
        ServerConfig::new(PDomain::Dmp, true, RqwrbLoc::Dram),
        AppendMode::Compound,
        Primary::Write,
    );
}
