//! Reactor event-loop scale sweep: the `runtime::reactor` virtual-time
//! scheduler driving 1k–10k client tasks (one QP each) through the
//! free-running completion-driven schedule, next to the lockstep
//! adapters' bit-for-bit equivalence with the legacy wave-pipelined
//! runners at conventional sizes.
//!
//! Results are persisted as a JSON artifact (`RPMEM_REACTOR_OUT`,
//! default `reactor_results.json`); the artifact is a pure function of
//! the seeds, so CI double-runs it and diffs the bytes. Three guards
//! are asserted:
//!
//! * **scaling monotonicity** — one QP per client means connections are
//!   the unit of RDMA scaling, so aggregate throughput must be
//!   monotonically non-decreasing along the client axis (noise floor
//!   0.1%); any regression fails the build;
//! * **adapter equivalence** — the put/txn/grouped reactor adapters
//!   reproduce the legacy runners' span, mean, and p99 *bit for bit* at
//!   matching client counts (the refactor cannot drift);
//! * **the loop really ran** — every point dispatched at least one
//!   event per append (posting and retiring are separate events).
//!
//! Fast mode: `RPMEM_BENCH_FAST=1` (CI bench-smoke job) still sweeps
//! 1000+ clients — the whole point of the reactor is that this is
//! cheap.

use rpmem::coordinator::scaling::{
    reactor_grid_to_json, render_reactor_grid, run_reactor_grid, ScalingOpts,
};
use rpmem::fabric::timing::TimingModel;
use rpmem::persist::config::{PDomain, RqwrbLoc, ServerConfig};
use rpmem::persist::groupcommit::GroupCommitOpts;
use rpmem::persist::method::Primary;
use rpmem::remotelog::client::{AppendMode, MethodChoice};
use rpmem::remotelog::pipeline::{
    run_multi_client, run_txn_grouped, run_txn_multi_shard, GroupRunOpts,
    ShardedRunOpts, TxnRunOpts,
};
use rpmem::runtime::reactor::{
    run_multi_client_reactor, run_txn_grouped_reactor,
    run_txn_multi_shard_reactor,
};
use std::time::Instant;

fn main() {
    let fast = rpmem::bench::fast();
    let clients: &[usize] =
        if fast { &[1000, 2000] } else { &[1000, 2500, 5000, 10000] };
    let appends: u64 = if fast { 8 } else { 100 };
    let capacity: u64 = if fast { 16 } else { 128 };
    let opts = ScalingOpts {
        appends_per_client: appends,
        capacity,
        ..Default::default()
    };
    let cfg = ServerConfig::new(PDomain::Mhp, false, RqwrbLoc::Dram);
    println!(
        "reactor event-loop sweep, {appends} appends/client, one QP per \
         client, clients {clients:?}\n"
    );

    let t0 = Instant::now();
    let points = run_reactor_grid(
        cfg,
        AppendMode::Singleton,
        Primary::Write,
        clients,
        &opts,
    );
    let wall = t0.elapsed();
    println!(
        "{}",
        render_reactor_grid(
            "reactor free-running schedule — MHP singleton, shards == clients",
            &points
        )
    );
    println!("  [harness: {:.2?} wall-clock]\n", wall);

    // Guard 1: throughput monotone along the client axis — one QP per
    // client adds capacity, so the event loop must deliver it.
    for w in points.windows(2) {
        assert!(
            w[1].throughput_mops >= w[0].throughput_mops * 0.999,
            "reactor scaling regressed: {} clients -> {:.3} Mops, {} \
             clients -> {:.3} Mops",
            w[0].clients,
            w[0].throughput_mops,
            w[1].clients,
            w[1].throughput_mops
        );
    }
    // Guard 3: the loop really ran — at least one dispatch per append.
    for p in &points {
        assert!(
            p.events >= p.appends,
            "{} clients: {} events for {} appends — the reactor cannot \
             have driven this run",
            p.clients,
            p.events,
            p.appends
        );
    }

    // Guard 2: lockstep adapters == legacy runners, bit for bit, at a
    // conventional size on every workload shape.
    let timing = TimingModel::default();
    let popts = ShardedRunOpts {
        clients: 12,
        shards: 3,
        window: 8,
        batch: 4,
        appends_per_client: 60,
        capacity: 64,
        seed: 42,
        record: false,
    };
    for mode in [AppendMode::Singleton, AppendMode::Compound] {
        let (_, legacy) = run_multi_client(
            cfg,
            timing.clone(),
            mode,
            MethodChoice::Planned(Primary::Write),
            &popts,
        );
        let (_, adapted) = run_multi_client_reactor(
            cfg,
            timing.clone(),
            mode,
            MethodChoice::Planned(Primary::Write),
            &popts,
        );
        assert_eq!(legacy.span_ns, adapted.span_ns, "{mode:?} span drifted");
        assert_eq!(
            legacy.mean_latency_ns.to_bits(),
            adapted.mean_latency_ns.to_bits(),
            "{mode:?} mean drifted"
        );
        assert_eq!(
            legacy.p99_latency_ns, adapted.p99_latency_ns,
            "{mode:?} p99 drifted"
        );
    }
    let topts = TxnRunOpts {
        clients: 4,
        shards: 3,
        txns_per_client: 24,
        capacity: 32,
        seed: 42,
        record: false,
        atomic: true,
        replicate: true,
    };
    let (_, tl) = run_txn_multi_shard(cfg, timing.clone(), Primary::Write, &topts);
    let (_, tr) =
        run_txn_multi_shard_reactor(cfg, timing.clone(), Primary::Write, &topts);
    assert_eq!(tl.span_ns, tr.span_ns, "txn span drifted");
    assert_eq!(tl.decision_ns_total, tr.decision_ns_total);
    assert_eq!(tl.mean_latency_ns.to_bits(), tr.mean_latency_ns.to_bits());
    let gopts = GroupRunOpts {
        clients: 4,
        shards: 3,
        txns_per_client: 24,
        capacity: 32,
        seed: 42,
        record: false,
        replicate: false,
        group: GroupCommitOpts { max_group: 4, ..Default::default() },
    };
    let (_, gl) = run_txn_grouped(cfg, timing.clone(), Primary::Write, &gopts);
    let (_, gr) =
        run_txn_grouped_reactor(cfg, timing.clone(), Primary::Write, &gopts);
    assert_eq!(gl.span_ns, gr.span_ns, "grouped span drifted");
    assert_eq!(gl.group_sizes, gr.group_sizes, "group boundaries drifted");
    assert_eq!(gl.mean_latency_ns.to_bits(), gr.mean_latency_ns.to_bits());
    println!(
        "adapter equivalence: put (singleton + compound), 2PC, grouped — \
         all bit-for-bit with the legacy runners\n"
    );

    let out = std::env::var("RPMEM_REACTOR_OUT")
        .unwrap_or_else(|_| "reactor_results.json".to_string());
    std::fs::write(&out, reactor_grid_to_json(&points).to_string_pretty())
        .expect("write reactor JSON artifact");
    println!("wrote {out} ({} points)", points.len());
}
