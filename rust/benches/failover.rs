//! Coordinator-failover replication tax: 2PC with decision records
//! mirrored to a witness shard (`persist::failover` — the ack point
//! moves to the witness shard's persistence point) vs plain single-ring
//! 2PC, across a clients × shards grid.
//!
//! Results are persisted as a JSON artifact (`RPMEM_FAILOVER_OUT`,
//! default `failover_results.json`). Two invariants are asserted:
//! surviving a coordinator-shard loss is never free (plain >= replicated
//! throughput at every point) but the tax is bounded (the witness write
//! rides a parallel QP, so replication keeps more than a third of the
//! plain-2PC throughput — one overlapped persistence point, not a second
//! serialized round trip). A small recording run additionally sweeps the
//! crash × shard-loss cross product so the bench can never report a tax
//! for a configuration whose recovery is broken.
//!
//! Fast mode: `RPMEM_BENCH_FAST=1` (CI bench-smoke job).

use rpmem::bench::scaled;
use rpmem::coordinator::scaling::{
    failover_grid_to_json, render_failover_grid, run_failover_grid,
    ScalingOpts,
};
use rpmem::fabric::timing::TimingModel;
use rpmem::persist::config::{PDomain, RqwrbLoc, ServerConfig};
use rpmem::persist::method::Primary;
use rpmem::remotelog::pipeline::{
    run_failover_sweep, run_txn_multi_shard, TxnRunOpts,
};
use rpmem::remotelog::recovery::RustScanner;
use std::time::Instant;

fn main() {
    let txns = scaled(2000);
    let clients = [1usize, 2];
    let shards = [2usize, 4, 8];
    let opts = ScalingOpts { capacity: txns.max(16), ..Default::default() };
    println!(
        "coordinator failover, {txns} txns/client, grid {clients:?} x {shards:?}\n"
    );

    let scenarios: [(&str, ServerConfig, Primary); 3] = [
        (
            "MHP one-sided Write;Flush phases",
            ServerConfig::new(PDomain::Mhp, false, RqwrbLoc::Dram),
            Primary::Write,
        ),
        (
            "DMP ¬DDIO one-sided Write;Flush phases",
            ServerConfig::new(PDomain::Dmp, false, RqwrbLoc::Dram),
            Primary::Write,
        ),
        (
            "DMP+DDIO two-sided Send phases (responder-CPU-bound)",
            ServerConfig::new(PDomain::Dmp, true, RqwrbLoc::Dram),
            Primary::Send,
        ),
    ];

    let mut all = Vec::new();
    for (title, cfg, primary) in scenarios {
        let t0 = Instant::now();
        let points =
            run_failover_grid(cfg, primary, &clients, &shards, txns, &opts);
        let wall = t0.elapsed();
        let label =
            format!("{title}  [{} | {}]", points[0].method_name, cfg.label());
        println!("{}", render_failover_grid(&label, &points));
        println!("  [harness: {:.2?} wall-clock]\n", wall);
        for p in &points {
            assert!(
                p.plain_mtps >= p.replicated_mtps * 0.999,
                "failover can't be free: {} clients x {} shards replicated \
                 {:.3} vs plain {:.3}",
                p.clients,
                p.shards,
                p.replicated_mtps,
                p.plain_mtps
            );
            assert!(
                p.replicated_mtps * 3.0 > p.plain_mtps,
                "replication collapsed: {} clients x {} shards {:.3} vs {:.3}",
                p.clients,
                p.shards,
                p.replicated_mtps,
                p.plain_mtps
            );
        }
        all.extend(points);
    }

    // Correctness smoke: the replicated protocol whose tax we just
    // measured must actually survive every single-shard loss.
    let sweep_opts = TxnRunOpts {
        clients: 1,
        shards: 2,
        txns_per_client: 6,
        capacity: 16,
        seed: 31,
        record: true,
        atomic: true,
        replicate: true,
    };
    let (run, _) = run_txn_multi_shard(
        ServerConfig::new(PDomain::Mhp, false, RqwrbLoc::Dram),
        TimingModel::default(),
        Primary::Write,
        &sweep_opts,
    );
    let rep = run_failover_sweep(&run, 20, 7, &RustScanner);
    assert!(rep.clean(), "failover recovery sweep: {rep:?}");
    println!(
        "failover sweep clean over {} crash × loss points",
        rep.crash_points
    );

    let out = std::env::var("RPMEM_FAILOVER_OUT")
        .unwrap_or_else(|_| "failover_results.json".to_string());
    std::fs::write(&out, failover_grid_to_json(&all).to_string_pretty())
        .expect("write failover JSON artifact");
    println!("wrote {out} ({} points)", all.len());
}
