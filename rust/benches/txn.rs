//! Cross-shard transaction throughput: the 2PC atomic-commit layer
//! (`persist::txn`) vs. the same update stream issued as independent
//! per-shard compound updates — the price of atomicity, across a
//! clients × shards grid.
//!
//! Results are persisted as a JSON artifact (`RPMEM_TXN_OUT`, default
//! `txn_results.json`). Two invariants are asserted: atomicity is never
//! free (independent >= 2PC throughput at every point) but its price is
//! bounded (2PC keeps more than a fifth of the independent throughput —
//! one decision round trip plus intents, not a serialization collapse).
//!
//! Fast mode: `RPMEM_BENCH_FAST=1` (CI bench-smoke job).

use rpmem::bench::scaled;
use rpmem::coordinator::scaling::{
    render_txn_grid, run_txn_grid, txn_grid_to_json, ScalingOpts,
};
use rpmem::persist::config::{PDomain, RqwrbLoc, ServerConfig};
use rpmem::persist::method::Primary;
use std::time::Instant;

fn main() {
    let txns = scaled(2000);
    let clients = [1usize, 2, 4];
    let shards = [1usize, 2, 4, 8];
    let opts = ScalingOpts { capacity: txns.max(16), ..Default::default() };
    println!(
        "cross-shard transactions, {txns} txns/client, grid {clients:?} x {shards:?}\n"
    );

    let scenarios: [(&str, ServerConfig, Primary); 3] = [
        (
            "MHP one-sided Write;Flush phases",
            ServerConfig::new(PDomain::Mhp, false, RqwrbLoc::Dram),
            Primary::Write,
        ),
        (
            "DMP ¬DDIO one-sided Write;Flush phases",
            ServerConfig::new(PDomain::Dmp, false, RqwrbLoc::Dram),
            Primary::Write,
        ),
        (
            "DMP+DDIO two-sided Send phases (responder-CPU-bound)",
            ServerConfig::new(PDomain::Dmp, true, RqwrbLoc::Dram),
            Primary::Send,
        ),
    ];

    let mut all = Vec::new();
    for (title, cfg, primary) in scenarios {
        let t0 = Instant::now();
        let points =
            run_txn_grid(cfg, primary, &clients, &shards, txns, &opts);
        let wall = t0.elapsed();
        let label =
            format!("{title}  [{} | {}]", points[0].method_name, cfg.label());
        println!("{}", render_txn_grid(&label, &points));
        println!("  [harness: {:.2?} wall-clock]\n", wall);
        for p in &points {
            assert!(
                p.independent_mtps >= p.txn_mtps * 0.999,
                "atomicity can't beat no-atomicity: {} clients x {} shards \
                 2PC {:.3} vs independent {:.3}",
                p.clients,
                p.shards,
                p.txn_mtps,
                p.independent_mtps
            );
            assert!(
                p.txn_mtps * 5.0 > p.independent_mtps,
                "2PC collapsed: {} clients x {} shards {:.3} vs {:.3}",
                p.clients,
                p.shards,
                p.txn_mtps,
                p.independent_mtps
            );
        }
        all.extend(points);
    }

    let out = std::env::var("RPMEM_TXN_OUT")
        .unwrap_or_else(|_| "txn_results.json".to_string());
    std::fs::write(&out, txn_grid_to_json(&all).to_string_pretty())
        .expect("write txn JSON artifact");
    println!("wrote {out} ({} points)", all.len());
}
