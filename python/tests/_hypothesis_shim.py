"""Seeded-example fallback for the ``hypothesis`` API surface these tests use.

When the real ``hypothesis`` package is importable it is re-exported
verbatim (CI installs it and gets full random generation + shrinking).
Offline environments without it fall back to a tiny deterministic
seeded-example mode: each ``@given`` test runs a fixed number of examples
drawn from a ``random.Random`` seeded by the test's qualified name, so
runs are reproducible and a failure names the exact generated arguments.

Only the strategy surface the modules under ``python/tests`` need is
implemented: ``integers``, ``sampled_from``, ``booleans``, ``lists``.
There is no shrinking — none of the current property tests depend on it
(they assert exact equality against oracles, so the first failing example
is already minimal enough to debug). A test that genuinely needs
shrinking should keep ``pytest.importorskip("hypothesis")`` instead of
importing from this shim.
"""

try:  # pragma: no cover - exercised implicitly by which env runs this
    from hypothesis import given, settings, strategies  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    import random
    import zlib

    # Examples per @given test in fallback mode. Comparable to the
    # max_examples the test profiles request from real hypothesis.
    _EXAMPLES = 12

    class _Strategy:
        """A value generator: ``draw(rng) -> value``."""

        def __init__(self, draw):
            self._draw = draw

        def draw(self, rng):
            return self._draw(rng)

    class _StrategiesModule:
        """Stand-in for ``hypothesis.strategies``."""

        @staticmethod
        def integers(min_value=0, max_value=None):
            lo = 0 if min_value is None else min_value
            hi = 2**63 if max_value is None else max_value
            return _Strategy(lambda rng: rng.randint(lo, hi))

        @staticmethod
        def sampled_from(options):
            opts = list(options)
            return _Strategy(lambda rng: opts[rng.randrange(len(opts))])

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: rng.random() < 0.5)

        @staticmethod
        def lists(elements, min_size=0, max_size=10):
            def draw(rng):
                n = rng.randint(min_size, max_size)
                return [elements.draw(rng) for _ in range(n)]

            return _Strategy(draw)

    strategies = _StrategiesModule()

    def given(*args, **strategy_kwargs):
        """Seeded-example ``@given``: keyword strategies only."""
        if args:
            raise TypeError(
                "the hypothesis shim supports keyword strategies only"
            )

        def decorate(func):
            def wrapper(*call_args):
                seed = zlib.crc32(func.__qualname__.encode())
                rng = random.Random(seed)
                for i in range(_EXAMPLES):
                    kwargs = {
                        name: strat.draw(rng)
                        for name, strat in sorted(strategy_kwargs.items())
                    }
                    try:
                        func(*call_args, **kwargs)
                    except Exception as exc:
                        raise AssertionError(
                            f"seeded example {i + 1}/{_EXAMPLES} failed "
                            f"(seed {seed}): {kwargs!r}"
                        ) from exc

            # Copy identity by hand; functools.wraps would also set
            # __wrapped__, which makes pytest read the original
            # signature and hunt for fixtures named like the strategy
            # kwargs.
            wrapper.__name__ = func.__name__
            wrapper.__qualname__ = func.__qualname__
            wrapper.__doc__ = func.__doc__
            wrapper.__module__ = func.__module__
            return wrapper

        return decorate

    class settings:  # noqa: N801 - mirrors the hypothesis class name
        """No-op stand-in: profiles only tune example counts/deadlines,
        which the fallback fixes at ``_EXAMPLES`` with no deadline."""

        def __init__(self, *args, **kwargs):
            pass

        def __call__(self, func):
            return func

        @staticmethod
        def register_profile(name, *args, **kwargs):
            pass

        @staticmethod
        def load_profile(name):
            pass


st = strategies
