"""AOT path: every exported entry point lowers to parseable HLO text and
the lowered computation produces the same numbers as the oracle when
executed through the local CPU PJRT client (the same engine the rust
runtime embeds)."""

import json

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile.aot import to_hlo_text
from compile.model import EXPORT_N, export_specs
from compile.kernels.ref import (
    PAYLOAD_WORDS,
    RECORD_WORDS,
    scan_ref,
    verify_ref,
    fletcher_ref,
)


@pytest.fixture(scope="module")
def lowered_texts():
    out = {}
    for name, (fn, specs) in export_specs().items():
        out[name] = to_hlo_text(jax.jit(fn).lower(*specs))
    return out


class TestHloText:
    def test_all_entry_points_lower(self, lowered_texts):
        assert set(lowered_texts) == {"checksum", "scan", "verify", "digest"}
        for text in lowered_texts.values():
            assert text.startswith("HloModule")

    def test_no_custom_calls(self, lowered_texts):
        """interpret=True must fully decompose pallas — a Mosaic
        custom-call in the HLO would be unloadable by the CPU client."""
        for name, text in lowered_texts.items():
            assert "custom-call" not in text, f"{name} has a custom-call"

    def test_entry_layout_shapes(self, lowered_texts):
        assert f"u32[{EXPORT_N},{PAYLOAD_WORDS}]" in lowered_texts["checksum"]
        assert f"u32[{EXPORT_N},{RECORD_WORDS}]" in lowered_texts["scan"]
        assert f"u32[{EXPORT_N},{RECORD_WORDS}]" in lowered_texts["verify"]

    def test_manifest_consistency(self, tmp_path, monkeypatch):
        """aot.py main() writes a manifest matching export_specs."""
        import sys
        from compile import aot

        monkeypatch.setattr(
            sys, "argv", ["aot", "--out-dir", str(tmp_path)]
        )
        aot.main()
        manifest = json.loads((tmp_path / "manifest.json").read_text())
        assert manifest["export_n"] == EXPORT_N
        assert set(manifest["artifacts"]) == {"checksum", "scan", "verify", "digest"}
        for name in manifest["artifacts"]:
            assert (tmp_path / f"{name}.hlo.txt").exists()


class TestExecuteLowered:
    """Compile the exported HLO with the CPU backend and compare numerics
    against the oracles — this is exactly what the rust runtime does."""

    def _run(self, name, *args):
        fn, specs = export_specs()[name]
        compiled = jax.jit(fn).lower(*specs).compile()
        return compiled(*args)

    def test_checksum_numerics(self):
        rng = np.random.default_rng(0)
        p = rng.integers(
            0, 2**32, size=(EXPORT_N, PAYLOAD_WORDS), dtype=np.uint32
        )
        recs = np.array(self._run("checksum", jnp.asarray(p)))
        s1, s2 = fletcher_ref(jnp.asarray(p))
        np.testing.assert_array_equal(recs[:, PAYLOAD_WORDS], np.array(s1))
        np.testing.assert_array_equal(recs[:, PAYLOAD_WORDS + 1], np.array(s2))

    def test_scan_numerics(self):
        rng = np.random.default_rng(1)
        from compile.model import checksum_records

        recs = np.array(
            checksum_records(
                jnp.asarray(
                    rng.integers(
                        0, 2**32, (EXPORT_N, PAYLOAD_WORDS), dtype=np.uint32
                    )
                )
            )
        )
        recs[777] ^= 3
        valid, tail = self._run("scan", jnp.asarray(recs))
        vr, tr = scan_ref(jnp.asarray(recs))
        np.testing.assert_array_equal(np.array(valid), np.array(vr))
        assert int(tail[0]) == int(tr[0]) == 777

    def test_verify_numerics(self):
        rng = np.random.default_rng(2)
        from compile.model import checksum_records

        p = rng.integers(0, 2**32, (EXPORT_N, PAYLOAD_WORDS), dtype=np.uint32)
        p[:, 0] = np.arange(50, 50 + EXPORT_N, dtype=np.uint32)
        recs = checksum_records(jnp.asarray(p))
        base = jnp.asarray([50], jnp.uint32)
        tail, vc, chain = self._run("verify", recs, base)
        t2, v2, c2 = verify_ref(recs, base)
        assert int(tail[0]) == int(t2[0])
        assert int(vc[0]) == int(v2[0])
        np.testing.assert_array_equal(np.array(chain), np.array(c2))
