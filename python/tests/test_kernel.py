"""L1 kernel correctness: Pallas kernels vs the pure-jnp oracles.

The hypothesis sweeps are the core correctness signal for the kernels: any
(shape, contents) divergence between the closed-form blocked kernels and
the sequential-recurrence oracle is a bug in one of them.
"""

import numpy as np
import jax.numpy as jnp
import pytest

# Real hypothesis when installed; deterministic seeded-example shim
# otherwise (no case here depends on shrinking).
from _hypothesis_shim import given, settings, st

from compile.kernels.ref import (
    PAYLOAD_WORDS,
    RECORD_WORDS,
    S1_WORD,
    S2_WORD,
    fletcher_ref,
    record_valid_ref,
    scan_ref,
    tail_ref,
)
from compile.kernels.fletcher import fletcher_pallas
from compile.kernels.scan import scan_pallas

settings.register_profile("kernels", deadline=None, max_examples=25)
settings.load_profile("kernels")

u32 = st.integers(min_value=0, max_value=2**32 - 1)


def _np_fletcher(payload: np.ndarray):
    """Third, numpy-side implementation of the spec — cross-checks the jnp
    oracle itself, not just kernel-vs-oracle."""
    s1 = np.ones(payload.shape[0], np.uint64)
    s2 = np.zeros(payload.shape[0], np.uint64)
    for i in range(payload.shape[1]):
        s1 = (s1 + payload[:, i]) & 0xFFFFFFFF
        s2 = (s2 + s1) & 0xFFFFFFFF
    return s1.astype(np.uint32), s2.astype(np.uint32)


def _records(rng, n, corrupt=()):
    payload = rng.integers(0, 2**32, size=(n, PAYLOAD_WORDS), dtype=np.uint32)
    s1, s2 = _np_fletcher(payload)
    recs = np.concatenate([payload, s1[:, None], s2[:, None]], axis=1)
    for idx in corrupt:
        recs[idx, rng.integers(0, RECORD_WORDS)] ^= 1 + rng.integers(0, 2**31)
    return recs


# ---------------------------------------------------------------- fletcher


class TestFletcherOracle:
    def test_matches_numpy_spec(self):
        rng = np.random.default_rng(1)
        p = rng.integers(0, 2**32, size=(64, PAYLOAD_WORDS), dtype=np.uint32)
        s1r, s2r = fletcher_ref(jnp.asarray(p))
        s1n, s2n = _np_fletcher(p)
        np.testing.assert_array_equal(np.array(s1r), s1n)
        np.testing.assert_array_equal(np.array(s2r), s2n)

    def test_zero_record_not_zero_checksum(self):
        p = jnp.zeros((4, PAYLOAD_WORDS), jnp.uint32)
        s1, s2 = fletcher_ref(p)
        assert (np.array(s1) == 1).all()
        assert (np.array(s2) == PAYLOAD_WORDS).all()

    def test_single_word_sensitivity(self):
        """Flipping any single payload word changes the checksum."""
        rng = np.random.default_rng(2)
        p = rng.integers(0, 2**32, size=(1, PAYLOAD_WORDS), dtype=np.uint32)
        s1, s2 = _np_fletcher(p)
        for i in range(PAYLOAD_WORDS):
            q = p.copy()
            q[0, i] ^= 0x1
            t1, t2 = _np_fletcher(q)
            assert (t1[0], t2[0]) != (s1[0], s2[0])

    def test_swap_detection(self):
        """Swapping two unequal words changes s2 (position-weighted)."""
        p = np.zeros((1, PAYLOAD_WORDS), np.uint32)
        p[0, 0], p[0, 1] = 7, 11
        q = p.copy()
        q[0, 0], q[0, 1] = 11, 7
        _, s2p = _np_fletcher(p)
        _, s2q = _np_fletcher(q)
        assert s2p[0] != s2q[0]


class TestFletcherKernel:
    @given(
        n_blocks=st.integers(1, 4),
        seed=st.integers(0, 2**31),
        block_n=st.sampled_from([8, 32, 256]),
    )
    def test_matches_ref_random(self, n_blocks, seed, block_n):
        rng = np.random.default_rng(seed)
        n = n_blocks * block_n
        p = rng.integers(0, 2**32, size=(n, PAYLOAD_WORDS), dtype=np.uint32)
        pj = jnp.asarray(p)
        s1k, s2k = fletcher_pallas(pj, block_n=block_n)
        s1r, s2r = fletcher_ref(pj)
        np.testing.assert_array_equal(np.array(s1k), np.array(s1r))
        np.testing.assert_array_equal(np.array(s2k), np.array(s2r))

    @given(fill=st.sampled_from([0, 1, 0xFFFFFFFF, 0x80000000]))
    def test_extreme_fills(self, fill):
        """Wraparound-heavy constant fills must wrap identically."""
        p = jnp.full((256, PAYLOAD_WORDS), fill, jnp.uint32)
        s1k, s2k = fletcher_pallas(p)
        s1r, s2r = fletcher_ref(p)
        np.testing.assert_array_equal(np.array(s1k), np.array(s1r))
        np.testing.assert_array_equal(np.array(s2k), np.array(s2r))

    @given(w=st.integers(1, 40), seed=st.integers(0, 2**31))
    def test_arbitrary_word_counts(self, w, seed):
        """Kernel is generic in W, not just the 14-word record layout."""
        rng = np.random.default_rng(seed)
        p = jnp.asarray(rng.integers(0, 2**32, size=(8, w), dtype=np.uint32))
        s1k, s2k = fletcher_pallas(p, block_n=8)
        s1r, s2r = fletcher_ref(p)
        np.testing.assert_array_equal(np.array(s1k), np.array(s1r))
        np.testing.assert_array_equal(np.array(s2k), np.array(s2r))

    def test_rejects_non_multiple_batch(self):
        with pytest.raises(ValueError, match="multiple"):
            fletcher_pallas(jnp.zeros((13, PAYLOAD_WORDS), jnp.uint32))


# -------------------------------------------------------------------- scan


class TestScanKernel:
    @given(
        seed=st.integers(0, 2**31),
        n_corrupt=st.integers(0, 6),
        block_n=st.sampled_from([8, 64, 256]),
    )
    def test_matches_ref_random_corruption(self, seed, n_corrupt, block_n):
        rng = np.random.default_rng(seed)
        n = 2 * block_n
        corrupt = rng.choice(n, size=n_corrupt, replace=False)
        recs = jnp.asarray(_records(rng, n, corrupt))
        vk, tk = scan_pallas(recs, block_n=block_n)
        vr, tr = scan_ref(recs)
        np.testing.assert_array_equal(np.array(vk), np.array(vr))
        assert int(tk[0]) == int(tr[0])

    def test_all_valid_tail_is_n(self):
        rng = np.random.default_rng(3)
        recs = jnp.asarray(_records(rng, 512))
        valid, tail = scan_pallas(recs)
        assert int(tail[0]) == 512
        assert np.array(valid).sum() == 512

    def test_all_zero_log_tail_is_zero(self):
        recs = jnp.zeros((512, RECORD_WORDS), jnp.uint32)
        valid, tail = scan_pallas(recs)
        assert int(tail[0]) == 0
        assert np.array(valid).sum() == 0

    @given(bad=st.integers(0, 511))
    def test_tail_is_first_invalid(self, bad):
        rng = np.random.default_rng(4)
        recs = _records(rng, 512)
        recs[bad, S1_WORD] ^= 0xDEAD
        _, tail = scan_pallas(jnp.asarray(recs))
        assert int(tail[0]) == bad

    def test_block_boundary_corruption(self):
        """First record of the second block — exercises the cross-block
        min-accumulation path."""
        rng = np.random.default_rng(5)
        recs = _records(rng, 512)
        recs[256, S2_WORD] ^= 1
        _, tail = scan_pallas(jnp.asarray(recs), block_n=256)
        assert int(tail[0]) == 256

    def test_valid_after_tail_still_reported(self):
        """The mask reports raw validity; prefix semantics are the
        caller's (tail is still the first invalid)."""
        rng = np.random.default_rng(6)
        recs = _records(rng, 512)
        recs[10, 0] ^= 0xFF  # invalidate record 10 only
        valid, tail = scan_pallas(jnp.asarray(recs))
        assert int(tail[0]) == 10
        assert np.array(valid)[11:].all()

    def test_rejects_wrong_word_count(self):
        with pytest.raises(ValueError, match="words"):
            scan_pallas(jnp.zeros((256, 8), jnp.uint32))

    def test_rejects_non_multiple_batch(self):
        with pytest.raises(ValueError, match="multiple"):
            scan_pallas(jnp.zeros((100, RECORD_WORDS), jnp.uint32))


class TestTailOracle:
    @given(bits=st.lists(st.booleans(), min_size=1, max_size=64))
    def test_tail_matches_python_scan(self, bits):
        valid = jnp.asarray(np.array(bits, np.uint32))
        expect = bits.index(False) if False in bits else len(bits)
        assert int(tail_ref(valid)) == expect
