"""Anti-entropy digest kernel vs oracle."""

import numpy as np
import jax.numpy as jnp
import pytest

# Real hypothesis when installed; deterministic seeded-example shim
# otherwise (no case here depends on shrinking).
from _hypothesis_shim import given, settings, st

from compile.kernels.digest import (
    SEG_RECORDS,
    segment_digest_pallas,
    segment_digest_ref,
)
from compile.kernels.ref import RECORD_WORDS
from compile.model import segment_digests

settings.register_profile("digest", deadline=None, max_examples=20)
settings.load_profile("digest")


def _records(rng, n):
    return rng.integers(0, 2**32, size=(n, RECORD_WORDS), dtype=np.uint32)


class TestDigestKernel:
    @given(n_seg=st.integers(1, 6), seed=st.integers(0, 2**31))
    def test_matches_ref(self, n_seg, seed):
        rng = np.random.default_rng(seed)
        r = jnp.asarray(_records(rng, n_seg * SEG_RECORDS))
        s1k, s2k = segment_digest_pallas(r)
        s1r, s2r = segment_digest_ref(r)
        np.testing.assert_array_equal(np.array(s1k), np.array(s1r))
        np.testing.assert_array_equal(np.array(s2k), np.array(s2r))

    @given(seg=st.sampled_from([8, 32, 64, 128]))
    def test_alternate_segment_sizes(self, seg):
        rng = np.random.default_rng(3)
        r = jnp.asarray(_records(rng, 2 * seg))
        s1k, s2k = segment_digest_pallas(r, seg_records=seg)
        s1r, s2r = segment_digest_ref(r, seg_records=seg)
        np.testing.assert_array_equal(np.array(s1k), np.array(s1r))
        np.testing.assert_array_equal(np.array(s2k), np.array(s2r))

    def test_single_word_divergence_changes_exactly_one_digest(self):
        rng = np.random.default_rng(4)
        a = _records(rng, 4 * SEG_RECORDS)
        b = a.copy()
        b[2 * SEG_RECORDS + 5, 3] ^= 1  # divergence in segment 2
        da = np.array(segment_digests(jnp.asarray(a)))
        db = np.array(segment_digests(jnp.asarray(b)))
        diff = np.where((da != db).any(axis=1))[0]
        np.testing.assert_array_equal(diff, [2])

    def test_swapped_records_within_segment_detected(self):
        rng = np.random.default_rng(5)
        a = _records(rng, SEG_RECORDS)
        b = a.copy()
        b[[0, 1]] = b[[1, 0]]
        da = np.array(segment_digests(jnp.asarray(a)))
        db = np.array(segment_digests(jnp.asarray(b)))
        assert (da != db).any(), "position-weighted digest must see swaps"

    def test_rejects_bad_shapes(self):
        with pytest.raises(ValueError, match="multiple"):
            segment_digest_pallas(jnp.zeros((SEG_RECORDS + 1, RECORD_WORDS), jnp.uint32))
        with pytest.raises(ValueError, match="words"):
            segment_digest_pallas(jnp.zeros((SEG_RECORDS, 8), jnp.uint32))
