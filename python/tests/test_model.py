"""L2 model correctness: entry-point composition semantics."""

import numpy as np
import jax.numpy as jnp
import pytest

# Real hypothesis when installed; deterministic seeded-example shim
# otherwise (no case here depends on shrinking).
from _hypothesis_shim import given, settings, st

from compile.kernels.ref import PAYLOAD_WORDS, RECORD_WORDS, verify_ref
from compile.model import (
    EXPORT_N,
    checksum_records,
    recover_scan,
    verify_segment,
)

settings.register_profile("model", deadline=None, max_examples=15)
settings.load_profile("model")


def _payloads(rng, n, seq0=None):
    p = rng.integers(0, 2**32, size=(n, PAYLOAD_WORDS), dtype=np.uint32)
    if seq0 is not None:
        p[:, 0] = np.arange(seq0, seq0 + n, dtype=np.uint32)
    return p


class TestChecksumRecords:
    def test_layout(self):
        """Output = payload words followed by the two checksum words."""
        rng = np.random.default_rng(0)
        p = _payloads(rng, 256)
        recs = np.array(checksum_records(jnp.asarray(p)))
        assert recs.shape == (256, RECORD_WORDS)
        np.testing.assert_array_equal(recs[:, :PAYLOAD_WORDS], p)

    def test_roundtrip_scan(self):
        """checksum_records output scans as fully valid."""
        rng = np.random.default_rng(1)
        recs = checksum_records(jnp.asarray(_payloads(rng, 512)))
        valid, tail = recover_scan(recs)
        assert int(tail[0]) == 512
        assert np.array(valid).all()

    @given(seed=st.integers(0, 2**31))
    def test_deterministic(self, seed):
        rng = np.random.default_rng(seed)
        p = jnp.asarray(_payloads(rng, 256))
        a = np.array(checksum_records(p))
        b = np.array(checksum_records(p))
        np.testing.assert_array_equal(a, b)


class TestRecoverScan:
    @given(cut=st.integers(0, 511))
    def test_partial_write_detected(self, cut):
        """A torn record (half old, half new) must break the prefix."""
        rng = np.random.default_rng(2)
        recs = np.array(checksum_records(jnp.asarray(_payloads(rng, 512))))
        torn = np.array(checksum_records(jnp.asarray(_payloads(rng, 512))))
        # Tear record `cut` halfway: first 8 words new, rest old.
        recs[cut, :8] = torn[cut, :8]
        _, tail = recover_scan(jnp.asarray(recs))
        assert int(tail[0]) == cut

    def test_erased_suffix(self):
        rng = np.random.default_rng(3)
        recs = np.array(checksum_records(jnp.asarray(_payloads(rng, 512))))
        recs[300:] = 0
        _, tail = recover_scan(jnp.asarray(recs))
        assert int(tail[0]) == 300


class TestVerifySegment:
    @given(seed=st.integers(0, 2**31), base=st.integers(0, 2**20))
    def test_matches_oracle(self, seed, base):
        rng = np.random.default_rng(seed)
        recs = checksum_records(jnp.asarray(_payloads(rng, 512, seq0=base)))
        bs = jnp.asarray([base], jnp.uint32)
        tail, vc, chain = verify_segment(recs, bs)
        t2, v2, c2 = verify_ref(recs, bs)
        assert int(tail[0]) == int(t2[0]) == 512
        assert int(vc[0]) == int(v2[0]) == 512
        np.testing.assert_array_equal(np.array(chain), np.array(c2))

    def test_sequence_gap_breaks_chain(self):
        """Checksum-valid records with a seq gap (lost ordered update —
        exactly the compound-update hazard of paper §3.3) stop the prefix."""
        rng = np.random.default_rng(4)
        p = _payloads(rng, 512, seq0=100)
        p[200:, 0] += 1  # records 200.. skip one sequence number
        recs = checksum_records(jnp.asarray(p))
        tail, vc, _ = verify_segment(recs, jnp.asarray([100], jnp.uint32))
        assert int(tail[0]) == 200
        assert int(vc[0]) == 512  # checksums all fine — only the chain broke

    def test_wrong_base_rejects_everything(self):
        rng = np.random.default_rng(5)
        recs = checksum_records(jnp.asarray(_payloads(rng, 512, seq0=7)))
        tail, _, _ = verify_segment(recs, jnp.asarray([8], jnp.uint32))
        assert int(tail[0]) == 0

    def test_seq_wraparound(self):
        """u32 sequence arithmetic wraps cleanly across 2^32."""
        rng = np.random.default_rng(6)
        base = 2**32 - 100
        p = _payloads(rng, 512)
        p[:, 0] = (base + np.arange(512, dtype=np.uint64)) & 0xFFFFFFFF
        recs = checksum_records(jnp.asarray(p))
        tail, _, _ = verify_segment(
            recs, jnp.asarray([base & 0xFFFFFFFF], jnp.uint32)
        )
        assert int(tail[0]) == 512


class TestExportShapes:
    def test_export_n_is_block_multiple(self):
        from compile.kernels.fletcher import BLOCK_N

        assert EXPORT_N % BLOCK_N == 0
