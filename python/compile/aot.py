"""AOT: lower the L2 entry points once to HLO *text* for the rust runtime.

HLO text — NOT ``lowered.compile()`` or serialized HloModuleProto — is the
interchange format: jax >= 0.5 emits protos with 64-bit instruction ids
which the xla crate's xla_extension 0.5.1 rejects (``proto.id() <=
INT_MAX``); the text parser reassigns ids and round-trips cleanly. See
/opt/xla-example/README.md.

Usage: ``cd python && python -m compile.aot --out-dir ../artifacts``
(invoked by ``make artifacts``; a no-op at the Makefile level when inputs
are unchanged). Also writes ``manifest.json`` recording shapes so the rust
runtime can sanity-check at load time.
"""

from __future__ import annotations

import argparse
import json
import os

import jax
from jax._src.lib import xla_client as xc

from .model import EXPORT_N, export_specs
from .kernels.ref import PAYLOAD_WORDS, RECORD_WORDS


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (ids reassigned by parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out-dir", default="../artifacts")
    args = parser.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = {
        "export_n": EXPORT_N,
        "payload_words": PAYLOAD_WORDS,
        "record_words": RECORD_WORDS,
        "artifacts": {},
    }
    for name, (fn, arg_specs) in export_specs().items():
        lowered = jax.jit(fn).lower(*arg_specs)
        text = to_hlo_text(lowered)
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest["artifacts"][name] = {
            "file": f"{name}.hlo.txt",
            "args": [
                {"shape": list(s.shape), "dtype": str(s.dtype)}
                for s in arg_specs
            ],
        }
        print(f"wrote {path} ({len(text)} chars)")

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {args.out_dir}/manifest.json")


if __name__ == "__main__":
    main()
