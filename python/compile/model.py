"""L2: the JAX compute graph for REMOTELOG record integrity.

Composes the L1 Pallas kernels (`kernels.fletcher`, `kernels.scan`) into
the three entry points the rust coordinator calls through PJRT:

  * ``checksum_records`` — requester append path (batched): payload words
    in, full record images (payload ‖ s1 ‖ s2) out, ready to be RDMA-written.
  * ``recover_scan``     — responder recovery path: scan a PM log region,
    return the per-record validity mask and the recovered tail index.
  * ``verify_segment``   — compound-log verification: validity ∧ sequence-
    chain check against the explicit tail pointer's base sequence.

Everything here is shape-static so `aot.py` can lower each entry point once
to HLO text; the rust runtime pads inputs to EXPORT_N records. Python never
runs on the request path — these functions exist to be lowered.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels.digest import segment_digest_pallas, SEG_RECORDS
from .kernels.fletcher import fletcher_pallas
from .kernels.scan import scan_pallas
from .kernels.ref import PAYLOAD_WORDS, RECORD_WORDS

# The batch size each AOT artifact is specialized to. Rust pads partial
# batches with zero records (which can never checksum as valid).
EXPORT_N = 1024


def checksum_records(payload: jax.Array) -> jax.Array:
    """(N, PAYLOAD_WORDS) u32 payloads -> (N, RECORD_WORDS) u32 record images.

    The emitted image is exactly what the requester RDMA-writes into the
    remote log: payload words followed by the two Fletcher words.
    """
    s1, s2 = fletcher_pallas(payload)
    return jnp.concatenate(
        [payload, s1[:, None], s2[:, None]], axis=1
    ).astype(jnp.uint32)


def recover_scan(records: jax.Array) -> tuple[jax.Array, jax.Array]:
    """(N, RECORD_WORDS) u32 log image -> (valid (N,) u32, tail (1,) u32)."""
    valid, tail = scan_pallas(records)
    return valid, tail.reshape((1,))


def verify_segment(
    records: jax.Array, base_seq: jax.Array
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Compound-log verification.

    ``records``: (N, RECORD_WORDS) u32; ``base_seq``: (1,) u32 — the
    sequence number the first record in the segment must carry (recovered
    from the persisted tail pointer).

    Returns (tail (1,), valid_count (1,), chain_ok (N,)): ``tail`` is the
    length of the longest prefix whose records are checksum-valid AND carry
    consecutive sequence numbers starting at ``base_seq``.
    """
    n = records.shape[0]
    valid, _ = scan_pallas(records)
    idx = jnp.arange(n, dtype=jnp.uint32)
    seq_ok = (records[:, 0] == (base_seq[0] + idx)).astype(jnp.uint32)
    chain_ok = valid & seq_ok
    first_bad = jnp.where(chain_ok == 0, idx, jnp.uint32(n))
    tail = jnp.min(first_bad, initial=jnp.uint32(n)).reshape((1,))
    valid_count = jnp.sum(valid, dtype=jnp.uint32).reshape((1,))
    return tail, valid_count, chain_ok


def segment_digests(records: jax.Array) -> jax.Array:
    """(N, RECORD_WORDS) u32 -> (N/SEG_RECORDS, 2) u32 anti-entropy
    digests; primary and replica compare these to locate divergence."""
    s1, s2 = segment_digest_pallas(records)
    return jnp.stack([s1, s2], axis=1)


def export_specs() -> dict[str, tuple]:
    """(fn, example-arg specs) for every AOT entry point, keyed by artifact
    name. Shared by `aot.py` and the python-side AOT tests."""
    u32 = jnp.uint32
    return {
        "checksum": (
            checksum_records,
            (jax.ShapeDtypeStruct((EXPORT_N, PAYLOAD_WORDS), u32),),
        ),
        "scan": (
            recover_scan,
            (jax.ShapeDtypeStruct((EXPORT_N, RECORD_WORDS), u32),),
        ),
        "verify": (
            verify_segment,
            (
                jax.ShapeDtypeStruct((EXPORT_N, RECORD_WORDS), u32),
                jax.ShapeDtypeStruct((1,), u32),
            ),
        ),
        "digest": (
            segment_digests,
            (jax.ShapeDtypeStruct((EXPORT_N, RECORD_WORDS), u32),),
        ),
    }


# Re-export for manifest consumers.
SEGMENT_RECORDS = SEG_RECORDS
