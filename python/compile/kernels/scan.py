"""L1 Pallas kernel: recovery scan — validity mask + log-tail detection.

This is the responder-side recovery hot-spot: after a power failure the
recovery subsystem scans the whole PM log region, recomputes every record's
Fletcher checksum, and finds the first invalid record — that index is the
recovered log tail (paper §4.1: "the server detects the log tail when its
checksum fails"). On multi-GiB logs this is a bandwidth-bound streaming
reduction, exactly the shape TPUs pipeline well.

Kernel structure: grid over (N // BLOCK_N) record blocks. Each step loads a
(BLOCK_N, RECORD_WORDS) tile into VMEM, recomputes the closed-form Fletcher
of the payload words, compares against the stored checksum words to emit
the per-record validity mask, and folds the block's first-invalid index
into a running global minimum. The tail output block-maps every grid step
to the same (1,) element; TPU grids (and interpret mode) execute
sequentially, so the read-modify-write accumulation is well-defined — this
is the standard Pallas cross-block reduction idiom.

VMEM per step (BLOCK_N=256): 256*16*4 B tile + masks ≈ 20 KiB.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import PAYLOAD_WORDS, RECORD_WORDS, S1_WORD, S2_WORD

BLOCK_N = 256


def _scan_block_kernel(rec_ref, valid_ref, tail_ref, *, block_n: int):
    i = pl.program_id(0)
    block = rec_ref[...]  # (BLOCK_N, RECORD_WORDS) u32
    payload = block[:, :PAYLOAD_WORDS]
    w = PAYLOAD_WORDS
    weights = jnp.uint32(w) - jax.lax.broadcasted_iota(jnp.uint32, (1, w), 1)
    s1 = jnp.uint32(1) + jnp.sum(payload, axis=1, dtype=jnp.uint32)
    s2 = jnp.uint32(w) + jnp.sum(payload * weights, axis=1, dtype=jnp.uint32)
    ok = (block[:, S1_WORD] == s1) & (block[:, S2_WORD] == s2)
    valid_ref[...] = ok.astype(jnp.uint32)

    # First-invalid index within this block, in global coordinates; records
    # with a valid checksum contribute the sentinel 0xFFFF_FFFF.
    local_idx = jax.lax.broadcasted_iota(jnp.uint32, (block_n,), 0)
    global_idx = jnp.uint32(i * block_n) + local_idx
    sentinel = jnp.uint32(0xFFFFFFFF)
    first_bad = jnp.min(jnp.where(ok, sentinel, global_idx))

    # Cross-block min-accumulation into the shared (1,) tail output.
    @pl.when(i == 0)
    def _init():
        tail_ref[...] = jnp.full((1,), sentinel, jnp.uint32)

    tail_ref[...] = jnp.minimum(tail_ref[...], first_bad.reshape((1,)))


@functools.partial(jax.jit, static_argnames=("block_n",))
def scan_pallas(records: jax.Array, *, block_n: int = BLOCK_N):
    """Scan (N, RECORD_WORDS) u32 records -> (valid (N,), tail (1,)).

    ``tail`` is the first checksum-invalid index, or N if all valid
    (the 0xFFFF_FFFF sentinel is clamped to N afterwards).
    """
    n, rw = records.shape
    if rw != RECORD_WORDS:
        raise ValueError(f"records must have {RECORD_WORDS} words, got {rw}")
    if n % block_n != 0:
        raise ValueError(f"N={n} must be a multiple of block_n={block_n}")
    grid = (n // block_n,)
    kernel = functools.partial(_scan_block_kernel, block_n=block_n)
    valid, tail = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block_n, rw), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((block_n,), lambda i: (i,)),
            # Every grid step maps to the same output element: the running
            # global minimum (sequential-grid reduction idiom).
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.uint32),
            jax.ShapeDtypeStruct((1,), jnp.uint32),
        ],
        interpret=True,
    )(records)
    return valid, jnp.minimum(tail, jnp.uint32(n))
