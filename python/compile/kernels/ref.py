"""Pure-jnp oracles for the L1 Pallas kernels.

These are the correctness ground truth: straightforward, loop-shaped
implementations of the REMOTELOG record-integrity math. The Pallas kernels
(`fletcher.py`, `scan.py`) must match these bit-for-bit; `python/tests/`
asserts that with hypothesis sweeps over shapes and contents, and the rust
mirror (`rust/src/remotelog/checksum.rs`) implements the identical spec so
requester-side (rust) and recovery-side (XLA) checksums agree.

Checksum spec (shared across all three layers)
----------------------------------------------
Fletcher-64/32-style dual accumulator over little-endian u32 words, all
arithmetic mod 2^32 (natural u32 wraparound):

    s1 = 1; s2 = 0
    for w in payload_words:
        s1 = (s1 + w)  mod 2^32
        s2 = (s2 + s1) mod 2^32

``s1`` starts at 1 (Adler-style) so the all-zero record does not checksum
to (0, 0): freshly-zeroed PM never looks like a valid record, which is what
lets REMOTELOG detect its tail by checksum failure (paper §4.1).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

# Record geometry: 64-byte log records = 16 u32 words; the last two words
# store (s1, s2). Matches rust/src/remotelog/log.rs.
RECORD_WORDS = 16
PAYLOAD_WORDS = 14
S1_WORD = 14
S2_WORD = 15


def fletcher_ref(payload: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Reference Fletcher over ``payload`` of shape (N, W) u32.

    Returns (s1, s2), each (N,) u32. Implemented as the literal sequential
    recurrence via lax.fori_loop — intentionally the dumbest correct form.
    """
    assert payload.dtype == jnp.uint32 and payload.ndim == 2
    n, w = payload.shape

    def body(i, carry):
        s1, s2 = carry
        s1 = s1 + payload[:, i]
        s2 = s2 + s1
        return s1, s2

    s1_0 = jnp.ones((n,), jnp.uint32)
    s2_0 = jnp.zeros((n,), jnp.uint32)
    s1, s2 = jax.lax.fori_loop(0, w, body, (s1_0, s2_0))
    return s1, s2


def record_valid_ref(records: jax.Array) -> jax.Array:
    """Validity mask for full (N, RECORD_WORDS) u32 record images.

    A record is valid iff the stored (s1, s2) words match the Fletcher of
    the payload words. Returns (N,) u32 in {0, 1}.
    """
    assert records.shape[1] == RECORD_WORDS
    s1, s2 = fletcher_ref(records[:, :PAYLOAD_WORDS])
    ok = (records[:, S1_WORD] == s1) & (records[:, S2_WORD] == s2)
    return ok.astype(jnp.uint32)


def tail_ref(valid: jax.Array) -> jax.Array:
    """First-invalid index (the recovered log tail) from a validity mask.

    Records at/after the first invalid one are ignored even if their
    checksums pass (stale survivors of GC): the log is a prefix.
    Returns () u32 == N when every record is valid.
    """
    n = valid.shape[0]
    idx = jnp.arange(n, dtype=jnp.uint32)
    first_bad = jnp.where(valid == 0, idx, jnp.uint32(n))
    return jnp.min(first_bad, initial=jnp.uint32(n))


def scan_ref(records: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Recovery scan oracle: (valid mask (N,), tail (1,))."""
    valid = record_valid_ref(records)
    return valid, tail_ref(valid).reshape((1,))


def verify_ref(
    records: jax.Array, base_seq: jax.Array
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Compound-update verification oracle.

    For the explicit-tail-pointer log (paper §4.1 compound case), record
    word 0 carries the append sequence number. A record participates in the
    recovered prefix iff its checksum is valid AND its sequence number is
    exactly ``base_seq + position`` (chain check — catches reordered /
    replayed records).

    Returns (tail (1,), valid_count (1,), chain_ok (N,)).
    """
    valid = record_valid_ref(records)
    n = records.shape[0]
    idx = jnp.arange(n, dtype=jnp.uint32)
    seq_ok = records[:, 0] == (base_seq[0] + idx)
    chain_ok = (valid & seq_ok.astype(jnp.uint32)).astype(jnp.uint32)
    tail = tail_ref(chain_ok).reshape((1,))
    valid_count = jnp.sum(valid, dtype=jnp.uint32).reshape((1,))
    return tail, valid_count, chain_ok
