"""L1 Pallas kernel: per-segment log digests for replica anti-entropy.

Log replication needs more than append-time persistence: after failovers,
a primary and a replica must cheaply agree on *where* their logs diverge.
The standard tool is segment digests — one checksum per fixed-size run of
records — compared pairwise; only diverging segments are re-shipped.

Kernel: one grid step per segment. A (SEG_RECORDS, RECORD_WORDS) tile is
loaded into VMEM and reduced with the same closed-form Fletcher used by
`fletcher.py`, but over the *flattened* segment (weights form a
(SEG, W) matrix of descending flat indices). Output is (s1, s2) per
segment. VMEM per step (SEG=64): 64*16*4 B tile + weights ≈ 8 KiB.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import RECORD_WORDS

# Records per digest segment (64 records = 4 KiB of log).
SEG_RECORDS = 64


def _digest_kernel(rec_ref, s1_ref, s2_ref):
    block = rec_ref[...]  # (SEG, RECORD_WORDS) u32
    seg, w = block.shape
    tot = jnp.uint32(seg * w)
    flat_idx = (
        jax.lax.broadcasted_iota(jnp.uint32, (seg, w), 0) * jnp.uint32(w)
        + jax.lax.broadcasted_iota(jnp.uint32, (seg, w), 1)
    )
    weights = tot - flat_idx  # weight of word k (flat) is TOT - k
    s1_ref[...] = (jnp.uint32(1) + jnp.sum(block, dtype=jnp.uint32)).reshape(
        (1,)
    )
    s2_ref[...] = (
        tot + jnp.sum(block * weights, dtype=jnp.uint32)
    ).reshape((1,))


@functools.partial(jax.jit, static_argnames=("seg_records",))
def segment_digest_pallas(
    records: jax.Array, *, seg_records: int = SEG_RECORDS
):
    """(N, RECORD_WORDS) u32 -> (s1 (N/seg,), s2 (N/seg,)) u32."""
    n, rw = records.shape
    if rw != RECORD_WORDS:
        raise ValueError(f"records must have {RECORD_WORDS} words, got {rw}")
    if n % seg_records != 0:
        raise ValueError(f"N={n} must be a multiple of {seg_records}")
    n_seg = n // seg_records
    return pl.pallas_call(
        _digest_kernel,
        grid=(n_seg,),
        in_specs=[pl.BlockSpec((seg_records, rw), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((1,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_seg,), jnp.uint32),
            jax.ShapeDtypeStruct((n_seg,), jnp.uint32),
        ],
        interpret=True,
    )(records)


def segment_digest_ref(records: jax.Array, seg_records: int = SEG_RECORDS):
    """Oracle: sequential Fletcher over each flattened segment."""
    from .ref import fletcher_ref

    n = records.shape[0]
    flat = records.reshape(n // seg_records, seg_records * records.shape[1])
    return fletcher_ref(flat)
