"""L1 Pallas kernel: blocked Fletcher checksum over batches of log records.

This is the requester-side hot-spot of REMOTELOG: every append must carry a
checksum (singleton appends are *detected* by checksum at the responder,
paper §4.1), and bulk replication checksums whole batches of records at
once. The kernel tiles the (N, W) u32 record matrix into (BLOCK_N, W)
VMEM-resident blocks and computes both Fletcher accumulators per record.

TPU mapping (DESIGN.md §Hardware-Adaptation): the checksum is an integer
reduction — VPU lane-parallel over records, not an MXU workload. Instead of
the sequential per-word recurrence the oracle uses, the kernel exploits the
closed form (all math mod 2^32):

    s1 = 1 + sum_i w_i
    s2 = W + sum_i (W - i) * w_i

which is two weighted reductions over the word axis — one fused pass over
the block, no loop-carried dependency, fully vectorizable. The weights
vector is a compile-time iota, so the whole kernel is: load block, two
multiply-accumulate reductions, store two (BLOCK_N,) vectors.

VMEM budget per grid step (BLOCK_N=256, W=14):
256*14*4 B input + 2*256*4 B output + 256*14*4 B weights-broadcast scratch
≈ 30 KiB, far under VMEM; double-buffering the input block is free.

interpret=True everywhere: the CPU PJRT plugin cannot run Mosaic
custom-calls; the lowered HLO is what `aot.py` exports for the rust side.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default record-batch tile. 256 records x 14 words keeps the working set
# ~30 KiB of VMEM while giving the VPU full lanes across the record axis.
BLOCK_N = 256


def _fletcher_block_kernel(rec_ref, s1_ref, s2_ref):
    """Per-block body: two weighted u32 reductions over the word axis."""
    block = rec_ref[...]  # (BLOCK_N, W) u32, resident in VMEM
    w = block.shape[1]
    # weights[i] = W - i, the closed-form multiplier for s2.
    weights = jnp.uint32(w) - jax.lax.broadcasted_iota(jnp.uint32, (1, w), 1)
    s1_ref[...] = jnp.uint32(1) + jnp.sum(block, axis=1, dtype=jnp.uint32)
    s2_ref[...] = jnp.uint32(w) + jnp.sum(
        block * weights, axis=1, dtype=jnp.uint32
    )


@functools.partial(jax.jit, static_argnames=("block_n",))
def fletcher_pallas(payload: jax.Array, *, block_n: int = BLOCK_N):
    """Checksum ``payload`` (N, W) u32 -> (s1 (N,), s2 (N,)) u32.

    N must be a multiple of ``block_n``; callers pad (a padded all-zero
    record checksums to (1, W), never colliding with stored zeros).
    """
    n, w = payload.shape
    if n % block_n != 0:
        raise ValueError(f"N={n} must be a multiple of block_n={block_n}")
    grid = (n // block_n,)
    return pl.pallas_call(
        _fletcher_block_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block_n, w), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((block_n,), lambda i: (i,)),
            pl.BlockSpec((block_n,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.uint32),
            jax.ShapeDtypeStruct((n,), jnp.uint32),
        ],
        interpret=True,
    )(payload)
