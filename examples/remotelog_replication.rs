//! End-to-end driver (the repository's headline validation run):
//! REMOTELOG log replication over every persistence domain, with both
//! singleton (checksummed records) and compound (explicit tail pointer)
//! appends, a mid-run power failure, and full recovery through the
//! AOT-compiled Pallas kernels when artifacts are available.
//!
//! Run: `make artifacts && cargo run --release --example remotelog_replication`
//! The output of this run is recorded in EXPERIMENTS.md.

use rpmem::fabric::timing::TimingModel;
use rpmem::persist::config::{PDomain, RqwrbLoc, ServerConfig};
use rpmem::persist::method::Primary;
use rpmem::remotelog::client::{AppendMode, MethodChoice, RemoteLog};
use rpmem::remotelog::log::RECORD_BYTES;
use rpmem::remotelog::recovery::{recover, RustScanner, Scanner};
use rpmem::runtime::XlaScanner;
use std::time::Instant;

fn main() {
    let appends = 2_000u64;
    let scanner: Box<dyn Scanner> = match XlaScanner::load("artifacts") {
        Ok(s) => {
            println!("recovery scanner: AOT Pallas kernels via PJRT");
            Box::new(s)
        }
        Err(e) => {
            println!("recovery scanner: rust mirror ({e})");
            Box::new(RustScanner)
        }
    };

    println!(
        "\n{:<26} {:<10} {:<9} {:>10} {:>9} {:>11} {:>10}",
        "config", "mode", "primary", "mean(us)", "p99(us)", "acked@cut", "recovered"
    );
    println!("{}", "-".repeat(92));

    let wall = Instant::now();
    let mut total_appends = 0u64;
    for pd in PDomain::ALL {
        for (mode, primary) in [
            (AppendMode::Singleton, Primary::Write),
            (AppendMode::Compound, Primary::Write),
            (AppendMode::Singleton, Primary::Send),
        ] {
            let rqwrb = if primary == Primary::Send {
                RqwrbLoc::Pm
            } else {
                RqwrbLoc::Dram
            };
            let cfg = ServerConfig::new(pd, pd == PDomain::Dmp, rqwrb);
            let mut rl = RemoteLog::new(
                cfg,
                TimingModel::default(),
                mode,
                MethodChoice::Planned(primary),
                appends + 8,
                0xFEED,
                true,
            );
            rl.run(appends);
            total_appends += appends;

            // Cut power right after the 70%-th ack.
            let cut = rl.appends[(appends * 7 / 10) as usize].acked_at + 1;
            let acked = rl.acked_before(cut);
            let image = rl.fab.mem.crash_image(cut, cfg.pdomain);
            let needs_replay = match mode {
                AppendMode::Singleton => rl.singleton_method().requires_replay(),
                AppendMode::Compound => rl.compound_method().requires_replay(),
            };
            let res = recover(
                &image,
                &rl.fab.mem.layout,
                &rl.log,
                mode,
                needs_replay,
                scanner.as_ref(),
            );
            // Verify the recovered prefix byte-for-byte.
            for k in 0..res.recovered as usize {
                assert_eq!(
                    &res.records[k * RECORD_BYTES..(k + 1) * RECORD_BYTES],
                    &rl.appends[k].record[..],
                    "{}: record {k} corrupt",
                    cfg.label()
                );
            }
            assert!(
                res.recovered >= acked,
                "{}: lost acked data",
                cfg.label()
            );
            println!(
                "{:<26} {:<10} {:<9} {:>10.2} {:>9.2} {:>11} {:>10}",
                cfg.label(),
                mode.name(),
                primary.name(),
                rl.latencies.summary().mean() / 1000.0,
                rl.latencies.quantile(0.99) as f64 / 1000.0,
                acked,
                res.recovered,
            );
        }
    }
    println!(
        "\n{} scenarios x {} appends each, all crash-recoveries verified, in {:.2?} wall-clock",
        9,
        appends,
        wall.elapsed()
    );
    let _ = total_appends;
}
