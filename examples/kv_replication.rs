//! Replicated KV store on the persistence planner: versioned A/B-slot
//! puts (strictly-ordered compound updates), a mid-run power failure,
//! and atomic recovery — acked puts survive, in-flight puts roll back,
//! torn values are impossible.
//!
//! Run: `cargo run --release --example kv_replication`

use rpmem::fabric::timing::TimingModel;
use rpmem::kvstore::{recover_kv, RemoteKv};
use rpmem::persist::config::{PDomain, RqwrbLoc, ServerConfig};
use rpmem::util::rng::SplitMix64;

fn main() {
    let cfg = ServerConfig::new(PDomain::Dmp, true, RqwrbLoc::Dram);
    let mut kv = RemoteKv::new(cfg, TimingModel::default(), 512, 7, true);
    println!("responder: {} | compound method: {}", cfg.label(), kv.method().name());

    let mut r = SplitMix64::new(1);
    let keys: Vec<u64> = (0..40).map(|_| r.next_u64() >> 16).collect();
    for i in 0..400u64 {
        let k = keys[r.next_below(keys.len() as u64) as usize];
        let v = format!("epoch{:03}:{:08x}", i, r.next_u32());
        kv.put(k, v.as_bytes());
    }
    println!("replicated 400 puts over {} keys", keys.len());

    // Power failure right in the middle of put #300's lifetime.
    let cut = (kv.puts[299].acked_at + kv.puts[300].acked_at) / 2;
    let acked = kv.acked_versions_at(cut);
    println!(
        "POWER FAILURE at t={:.1}us — {} puts acked, 1 in flight",
        cut as f64 / 1000.0,
        kv.puts.iter().filter(|p| p.acked_at <= cut).count()
    );

    let image = kv.fab.mem.crash_image(cut, cfg.pdomain);
    let state = recover_kv(&image, 512);
    println!("recovered {} live keys", state.len());

    let mut rolled_back = 0;
    for (key, rec) in &acked {
        let (v, val) = state
            .get(key)
            .unwrap_or_else(|| panic!("acked key {key:#x} lost!"));
        assert!(*v >= rec.version, "key {key:#x} regressed");
        if *v == rec.version {
            assert_eq!(val, &rec.value, "torn value for {key:#x}");
        } else {
            rolled_back += 1; // newer un-acked version happened to persist
        }
    }
    println!(
        "verified: every acked put recovered intact ({} keys carried a \
         durable-but-unacked newer version)",
        rolled_back
    );
    println!("OK — no loss, no tears, atomic rollback of the in-flight put");
}
