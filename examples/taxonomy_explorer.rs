//! Taxonomy explorer: for every server configuration, show the planned
//! method, its measured latency, and what happens if you apply the
//! WRONG method (latency of the cheaper-but-unsound alternative and the
//! data loss it causes) — the paper's core message in one table.
//!
//! Run: `cargo run --release --example taxonomy_explorer`

use rpmem::fabric::timing::TimingModel;
use rpmem::persist::config::ServerConfig;
use rpmem::persist::method::{Primary, SingletonMethod};
use rpmem::persist::planner::plan_singleton;
use rpmem::remotelog::client::{AppendMode, MethodChoice, RemoteLog};
use rpmem::remotelog::crashtest::crash_sweep;
use rpmem::remotelog::recovery::RustScanner;

fn measure(cfg: ServerConfig, choice: MethodChoice, appends: u64) -> (f64, bool) {
    let mut worst_clean = true;
    let mut mean = 0.0;
    for seed in 0..6u64 {
        let mut rl = RemoteLog::new(
            cfg,
            TimingModel::default(),
            AppendMode::Singleton,
            choice,
            appends + 8,
            seed * 31 + 1,
            true,
        );
        rl.run(appends);
        mean = rl.latencies.summary().mean();
        let rep = crash_sweep(&rl, 60, seed, &RustScanner);
        worst_clean &= rep.clean();
        if !worst_clean {
            break;
        }
    }
    (mean, worst_clean)
}

fn main() {
    // The tempting-but-possibly-wrong "fast path" everyone wants to use:
    // one-sided WRITE + FLUSH.
    let shortcut = SingletonMethod::WriteFlush;
    println!(
        "{:<26} {:<26} {:>9}   {:<22} {:>9}  {}",
        "config", "planned method", "us", "shortcut (Write;Flush)", "us", "safe?"
    );
    println!("{}", "-".repeat(108));
    for cfg in ServerConfig::table1() {
        let planned = plan_singleton(&cfg, Primary::Write);
        let (planned_us, planned_ok) =
            measure(cfg, MethodChoice::Planned(Primary::Write), 30);
        assert!(planned_ok, "planner produced an unsafe method for {cfg}!");
        let (shortcut_us, shortcut_ok) = measure(
            cfg,
            MethodChoice::ForcedSingleton(shortcut),
            30,
        );
        println!(
            "{:<26} {:<26} {:>9.2}   {:<22} {:>9.2}  {}",
            cfg.label(),
            planned.name(),
            planned_us / 1000.0,
            if planned == shortcut { "(same)" } else { "Write;Flush" },
            shortcut_us / 1000.0,
            if shortcut_ok {
                "yes"
            } else {
                "NO — loses acked data"
            }
        );
    }
    println!(
        "\nThe shortcut is faster wherever the planner prescribes message \
         passing —\nand silently loses acknowledged data on exactly those \
         configurations (paper §3.2/§5)."
    );
}
