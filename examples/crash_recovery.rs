//! Crash-recovery deep dive: watch what each persistence domain saves as
//! a function of *when* power fails, for the same op trace — the paper's
//! Figure 1 persistence domains made tangible. Also measures the XLA vs
//! rust recovery-scan agreement and throughput on a larger log.
//!
//! Run: `cargo run --release --example crash_recovery`

use rpmem::fabric::timing::TimingModel;
use rpmem::persist::config::{PDomain, RqwrbLoc, ServerConfig};
use rpmem::persist::method::Primary;
use rpmem::remotelog::client::{AppendMode, MethodChoice, RemoteLog};
use rpmem::remotelog::log::{make_record, APP_WORDS, RECORD_BYTES};
use rpmem::remotelog::recovery::{recover, RustScanner, Scanner};
use rpmem::runtime::XlaScanner;
use std::time::Instant;

fn main() {
    // ---- Part 1: one op trace, three persistence-domain lenses. ----
    // The same WSP-correct completion-only workload, crashed at the same
    // instants, recovers very different amounts depending on the domain.
    println!("== what survives, by persistence domain ==");
    println!("(WRITE;Comp appends — sound for WSP only; DMP/MHP lose tail data)\n");
    let mut rl = RemoteLog::new(
        ServerConfig::new(PDomain::Wsp, false, RqwrbLoc::Dram),
        TimingModel::default(),
        AppendMode::Singleton,
        MethodChoice::Planned(Primary::Write),
        64,
        99,
        true,
    );
    rl.run(40);
    // Crash *inside* append #20's in-flight window: the payload is on
    // the wire / in RNIC buffers / in the cache at these instants, so
    // the three domains disagree about what survives.
    let ack20 = rl.appends[20].acked_at;
    println!("{:>12}  {:>6} {:>6} {:>6}", "crash at", "DMP", "MHP", "WSP");
    for back in [2000u64, 1500, 1000, 600, 300, 0] {
        let t = ack20 - back;
        let mut row = format!("ack20-{:<4}ns ", back);
        for pd in PDomain::ALL {
            let img = rl.fab.mem.crash_image(t, pd);
            let res = recover(
                &img,
                &rl.fab.mem.layout,
                &rl.log,
                AppendMode::Singleton,
                false,
                &RustScanner,
            );
            row.push_str(&format!(" {:>6}", res.recovered));
        }
        println!("{row}");
    }
    println!("(records recovered out of 40 appended)\n");

    // ---- Part 2: recovery-scan backends on a large log. ----
    println!("== recovery scan: rust mirror vs AOT Pallas kernel ==");
    let n = 200_000usize;
    let mut log = Vec::with_capacity(n * RECORD_BYTES);
    for s in 0..n {
        log.extend_from_slice(&make_record(s as u64, &[s as u32; APP_WORDS]));
    }
    // Torn write near the end.
    let torn = n - 137;
    log[torn * RECORD_BYTES + 5] ^= 0x80;

    let t0 = Instant::now();
    let (_, tail_rust) = RustScanner.scan(&log);
    let rust_time = t0.elapsed();
    println!(
        "rust mirror : tail={tail_rust} in {:.2?} ({:.2} GiB/s)",
        rust_time,
        log.len() as f64 / rust_time.as_nanos() as f64 / 1.073_741_824
    );

    match XlaScanner::load("artifacts") {
        Ok(xla) => {
            let t0 = Instant::now();
            let (_, tail_xla) = xla.scan(&log);
            let xla_time = t0.elapsed();
            println!(
                "xla pallas  : tail={tail_xla} in {:.2?} ({:.2} GiB/s)",
                xla_time,
                log.len() as f64 / xla_time.as_nanos() as f64 / 1.073_741_824
            );
            assert_eq!(tail_rust, tail_xla, "scan backends disagree!");
            println!("backends agree: tail = {} (torn record at {})", tail_rust, torn);
        }
        Err(e) => println!("xla pallas  : skipped ({e})"),
    }
}
