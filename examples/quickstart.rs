//! Quickstart: ask the planner for the correct persistence method for
//! your server, then persist a remote update with it and prove it
//! survives a power failure.
//!
//! Run: `cargo run --release --example quickstart`

use rpmem::fabric::engine::Fabric;
use rpmem::fabric::timing::TimingModel;
use rpmem::persist::config::{PDomain, RqwrbLoc, ServerConfig};
use rpmem::persist::exec::{exec_singleton, Update};
use rpmem::persist::method::Primary;
use rpmem::persist::planner::plan_singleton;
use rpmem::server::memory::Layout;

fn main() {
    // 1. Describe the remote server: the dominant near-term config —
    //    ADR-style persistence (DMP) with DDIO enabled (paper §3.1).
    let cfg = ServerConfig::new(PDomain::Dmp, true, RqwrbLoc::Dram);
    println!("responder config : {cfg}");

    // 2. Ask the planner for the correct method (Table 2).
    let method = plan_singleton(&cfg, Primary::Write);
    println!("planned method   : {}", method.name());
    for step in method.steps() {
        println!("                   {step}");
    }

    // 3. Connect a simulated fabric and persist an update.
    let layout = Layout::new(1 << 20, 1 << 20, 64, 4096, cfg.rqwrb);
    let mut fab = Fabric::new(cfg, TimingModel::default(), layout, 1, true);
    let update = Update::new(0x1000, b"hello, remote persistence!......".to_vec());
    let outcome = exec_singleton(&mut fab, method, &update, 0);
    println!(
        "persisted in     : {:.2} us (virtual)",
        outcome.latency() as f64 / 1000.0
    );

    // 4. Power-fail the responder immediately after the ack and prove
    //    the data survived.
    let image = fab.mem.crash_image(outcome.acked, cfg.pdomain);
    assert_eq!(image.read(0x1000, update.data.len()), &update.data[..]);
    println!("power failure at ack+0ns: data intact ✓");

    // 5. Counter-example: the one-sided method that is only correct
    //    with DDIO off loses the data here (paper §3.2).
    use rpmem::persist::method::SingletonMethod;
    let mut fab2 = Fabric::new(
        cfg,
        TimingModel::default(),
        Layout::new(1 << 20, 1 << 20, 64, 4096, cfg.rqwrb),
        1,
        true,
    );
    let bad = exec_singleton(&mut fab2, SingletonMethod::WriteFlush, &update, 0);
    let image = fab2.mem.crash_image(bad.acked, cfg.pdomain);
    assert_eq!(image.read(0x1000, 4), &[0u8; 4]);
    println!(
        "wrong method (WRITE;FLUSH on DMP+DDIO): acked data LOST ✗ — \
         this is why the taxonomy matters"
    );
}
